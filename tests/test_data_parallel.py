"""Data-parallel bitwise-identity suite — the proof behind docs/PARALLEL.md.

NITRO-D's gradients are int32 batch sums, and int32 addition is exact and
associative — so sharding the batch over a ``data`` mesh, all-reducing
per-shard gradients, and applying IntegerSGD must reproduce the
single-device ``les.train_step`` **bit for bit**.  This file turns that
"must" into assertions, at three strengths:

  * in-process: ``dp_train_step`` over a real (1-device) mesh ≡
    ``train_step``, for every reducer; the sharded step's jaxpr is
    float-free (descending into the shard_map interior); telemetry
    on/off cannot perturb the sharded trajectory;
  * a quick 2-device smoke: subprocess workers (fresh interpreters with
    ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — the flag is
    dead after backend init, hence the subprocess) prove 2-device psum ≡
    single-device on the multi-step trajectory;
  * the full ``slow`` matrix: device counts {2, 4} × reducers
    {psum, ring, compress} × configs {tiny-with-dropout, scaled VGG8B},
    every cell compared leaf-by-leaf, dtype-exact, against the same
    single-device reference — plus telemetry equality under sharding.

The tiny config has dropout on *both* blocks deliberately: dropout is
the only sampled op in the step, and its global-mask-then-slice DP path
(``layers.dropout_forward``) is exactly what these trajectories would
expose if it diverged.
"""

from __future__ import annotations

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _gradcheck import assert_bitwise_equal, assert_jaxpr_integer_only
from repro.core import blocks as B
from repro.core import les
from repro.core import model as M
from repro.parallel import dp

_WORKER = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       "_dp_worker.py")


def tiny_dp_cfg() -> M.NitroConfig:
    """Conv + linear blocks, dropout on both — must match _dp_worker.py."""
    return M.NitroConfig(
        blocks=(
            B.BlockSpec(kind="conv", out_features=16, pool=True,
                        d_lr=256, dropout=0.1),
            B.BlockSpec(kind="linear", out_features=64, dropout=0.1),
        ),
        input_shape=(8, 8, 3), num_classes=10, gamma_inv=512,
    )


@pytest.fixture(scope="module")
def toy_batch():
    cfg = tiny_dp_cfg()
    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.integers(-128, 128, (8, *cfg.input_shape)), jnp.int32)
    labels = jnp.asarray(rng.integers(0, 10, (8,)), jnp.int32)
    return cfg, x, labels


@pytest.fixture(scope="module")
def dp_run(tmp_path_factory):
    """Callable running one (devices, reducer, config) worker cell in a
    fresh interpreter; results cached for the whole module so the
    single-device reference is computed once per config."""
    cache: dict[tuple, dict] = {}
    out_dir = tmp_path_factory.mktemp("dp_npz")

    def run(*, devices: int, reducer: str, config: str = "tiny",
            steps: int = 3, batch: int = 8, telemetry: bool = False,
            fuse_opt: bool = False) -> dict:
        key = (devices, reducer, config, steps, batch, telemetry, fuse_opt)
        if key not in cache:
            out = out_dir / ("_".join(str(p) for p in key) + ".npz")
            env = dict(os.environ)
            env.pop("XLA_FLAGS", None)  # worker sets its own device count
            cmd = [sys.executable, _WORKER, "--out", str(out),
                   "--devices", str(devices), "--reducer", reducer,
                   "--config", config, "--steps", str(steps),
                   "--batch", str(batch)]
            if telemetry:
                cmd.append("--telemetry")
            if fuse_opt:
                cmd.append("--fuse-opt")
            proc = subprocess.run(cmd, env=env, capture_output=True,
                                  text=True, timeout=900)
            assert proc.returncode == 0, (
                f"worker {key} failed:\n{proc.stdout}\n{proc.stderr}")
            with np.load(out) as z:
                cache[key] = {k: z[k] for k in z.files}
        return cache[key]

    return run


def assert_runs_bitwise_equal(got: dict, want: dict) -> None:
    """Every npz entry — final-state leaves, per-step metric trajectories,
    telemetry leaves — equal bit for bit, dtypes included."""
    assert sorted(got) == sorted(want)
    for k in sorted(got):
        assert got[k].dtype == want[k].dtype, (k, got[k].dtype, want[k].dtype)
        np.testing.assert_array_equal(got[k], want[k], err_msg=k)


# ---------------------------------------------------------------------------
# In-process: real mesh + shard_map semantics without extra devices
# ---------------------------------------------------------------------------


class TestInProcess:
    @pytest.mark.parametrize("reducer", dp.REDUCERS)
    def test_dp_step_matches_train_step(self, toy_batch, reducer):
        """1-device mesh, every reducer: the sharded step *is* train_step."""
        cfg, x, labels = toy_batch
        state_ref = state_dp = les.create_train_state(jax.random.PRNGKey(0), cfg)
        mesh = dp.data_mesh(1)
        step_dp = dp.make_dp_train_step(cfg, mesh, dp_reduce=reducer)
        step_ref = jax.jit(
            lambda s, x, l, k: les.train_step(s, cfg, x, l, k))
        for i in range(2):
            key = jax.random.PRNGKey(100 + i)
            state_ref, m_ref = step_ref(state_ref, x, labels, key)
            state_dp, m_dp = step_dp(state_dp, x, labels, key)
        assert_bitwise_equal(state_dp, state_ref)
        assert_bitwise_equal(m_dp, m_ref)

    def test_sharded_step_jaxpr_is_float_free(self, toy_batch):
        """Integer-only all the way down — iter_eqns descends into the
        shard_map sub-jaxpr, so the sharded interior is checked too."""
        cfg, x, labels = toy_batch
        state = les.create_train_state(jax.random.PRNGKey(0), cfg)
        mesh = dp.data_mesh(1)
        jaxpr = jax.make_jaxpr(
            lambda s, x, l, k: dp.dp_train_step(
                s, cfg, x, l, k, mesh=mesh, dp_reduce="ring"))(
            state, x, labels, jax.random.PRNGKey(1))
        prims = {e.primitive.name for e in jaxpr.eqns}
        assert "shard_map" in prims  # really testing the sharded program
        assert_jaxpr_integer_only(jaxpr)

    def test_telemetry_on_off_identity_under_sharding(self, toy_batch):
        cfg, x, labels = toy_batch
        state = les.create_train_state(jax.random.PRNGKey(0), cfg)
        key = jax.random.PRNGKey(7)
        mesh = dp.data_mesh(1)
        st_t, m_t, telem = dp.make_dp_train_step(cfg, mesh, telemetry=True)(
            state, x, labels, key)
        st_p, m_p = dp.make_dp_train_step(cfg, mesh)(state, x, labels, key)
        assert_bitwise_equal(st_t, st_p)
        assert_bitwise_equal(m_t, m_p)
        # the `dp` entry is topology-scoped (shard count, limb-fit flag)
        # and deliberately absent from the single-device readout
        dp_extra = telem.pop("dp")
        assert int(dp_extra["shards"]) == 1
        assert int(dp_extra["grad_fits_int16"]) in (0, 1)
        # and the readout itself matches the single-device readout
        _, _, telem_ref = jax.jit(
            lambda s, x, l, k: les.train_step(
                s, cfg, x, l, k, telemetry=True))(state, x, labels, key)
        assert_bitwise_equal(telem, telem_ref)

    def test_unknown_reducer_rejected(self, toy_batch):
        cfg, x, labels = toy_batch
        state = les.create_train_state(jax.random.PRNGKey(0), cfg)
        with pytest.raises(ValueError, match="dp_reduce"):
            dp.dp_train_step(state, cfg, x, labels, jax.random.PRNGKey(0),
                             mesh=dp.data_mesh(1), dp_reduce="avg")
        with pytest.raises(ValueError, match="dp_reduce"):
            dp.reduce_gradients({"w": x}, "data", "avg")

    def test_oversubscribed_mesh_rejected(self):
        n = jax.device_count() + 1
        with pytest.raises(ValueError, match="xla_force_host_platform"):
            dp.data_mesh(n)


# ---------------------------------------------------------------------------
# Subprocess: real forced host devices
# ---------------------------------------------------------------------------


class TestDeviceCounts:
    def test_two_device_psum_smoke(self, dp_run):
        """The quick-gate cell: 2 real devices, default reducer, full
        trajectory ≡ single-device."""
        ref = dp_run(devices=1, reducer="single")
        got = dp_run(devices=2, reducer="psum")
        assert_runs_bitwise_equal(got, ref)

    def test_two_device_fused_sgd_apply_smoke(self, dp_run):
        """DP post-reduce fused IntegerSGD apply (``fuse_opt=True`` — the
        standalone kernel consumes the all-reduced gradient) keeps the
        2-device trajectory bitwise equal to the plain single-device
        reference, proving both the fusion identity and the
        cross-device-count identity in one comparison."""
        ref = dp_run(devices=1, reducer="single")
        got = dp_run(devices=2, reducer="psum", fuse_opt=True)
        assert_runs_bitwise_equal(got, ref)

    @pytest.mark.slow
    @pytest.mark.parametrize("reducer", dp.REDUCERS)
    @pytest.mark.parametrize("devices", [2, 4])
    def test_tiny_trajectory_identical(self, dp_run, devices, reducer):
        ref = dp_run(devices=1, reducer="single")
        got = dp_run(devices=devices, reducer=reducer)
        assert_runs_bitwise_equal(got, ref)

    @pytest.mark.slow
    @pytest.mark.parametrize("reducer", dp.REDUCERS)
    @pytest.mark.parametrize("devices", [2, 4])
    def test_vgg8b_trajectory_identical(self, dp_run, devices, reducer):
        """The paper CNN (CPU-test scale): same equality, real conv stack."""
        ref = dp_run(devices=1, reducer="single", config="vgg8b", steps=2)
        got = dp_run(devices=devices, reducer=reducer,
                     config="vgg8b", steps=2)
        assert_runs_bitwise_equal(got, ref)

    @pytest.mark.slow
    def test_telemetry_identical_across_devices(self, dp_run):
        """Per-layer bit histograms / saturation / dead counts psum'd over
        shards must equal the single-device full-batch readout exactly."""
        ref = dp_run(devices=1, reducer="single", telemetry=True)
        got = dp_run(devices=4, reducer="psum", telemetry=True)
        assert_runs_bitwise_equal(got, ref)
