"""launch.obs_top: dashboard rendering, pinned by a golden frame.

The dashboard is a pure reader (telemetry JSONL + a /metrics.json
snapshot in, text out) and ``render_frame`` is deliberately
wall-clock-free — so the whole surface is testable as data → frame:

  * unit pieces: sparkline scaling, histogram-bucket quantile estimate,
    JSONL tail windowing;
  * the golden test: the checked-in fixtures under ``tests/data/``
    must render byte-identical to ``obs_top_frame.txt`` (regenerate
    with ``python -m repro.launch.obs_top --metrics
    tests/data/obs_top_metrics.jsonl --fleet-json
    tests/data/obs_top_fleet.json --once > tests/data/obs_top_frame.txt``
    after an intentional layout change);
  * the CLI ``--once`` path end-to-end in a subprocess (what
    tools/ci_check.sh smokes).
"""

import json
import os
import subprocess
import sys

from repro.launch import obs_top

DATA = os.path.join(os.path.dirname(__file__), "data")
METRICS = os.path.join(DATA, "obs_top_metrics.jsonl")
FLEET = os.path.join(DATA, "obs_top_fleet.json")
GOLDEN = os.path.join(DATA, "obs_top_frame.txt")


class TestPieces:
    def test_sparkline_zero_stays_blank(self):
        s = obs_top.sparkline([0, 1, 0, 1000])
        assert len(s) == 4
        assert s[0] == " " and s[2] == " "
        assert s[3] == obs_top.SPARK[-1]       # the max gets the full bar
        assert s[1] != " "                      # log scale: 1 still visible
        assert obs_top.sparkline([0, 0]) == "  "

    def test_quantile_from_buckets(self):
        buckets = [[0.01, 0], [0.1, 90], [1.0, 100], ["+Inf", 100]]
        assert obs_top.quantile_from_buckets(buckets, 100, 0.5) == 0.1
        assert obs_top.quantile_from_buckets(buckets, 100, 0.99) == 1.0
        assert obs_top.quantile_from_buckets(buckets, 0, 0.5) is None
        # rank past the last finite bound falls back to it
        tail = [[0.01, 0], ["+Inf", 10]]
        assert obs_top.quantile_from_buckets(tail, 10, 0.5) == 0.01

    def test_read_jsonl_tail_windows_by_step(self, tmp_path):
        path = tmp_path / "m.jsonl"
        rows = [{"step": s, "layer": "block0"} for s in range(10)]
        path.write_text("".join(json.dumps(r) + "\n" for r in rows))
        tail = obs_top.read_jsonl_tail(str(path), steps=3)
        assert sorted({r["step"] for r in tail}) == [7, 8, 9]

    def test_fleet_panel_without_serving_metrics(self):
        assert obs_top.render_fleet_panel({}) == [
            "fleet", "no serving metrics in snapshot"]


class TestGoldenFrame:
    def test_fixture_renders_byte_identical(self):
        with open(FLEET) as f:
            fleet = json.load(f)
        frame = obs_top.render_frame(METRICS, fleet)
        with open(GOLDEN) as f:
            golden = f.read()
        assert frame + "\n" == golden

    def test_frame_is_deterministic(self):
        with open(FLEET) as f:
            fleet = json.load(f)
        assert (obs_top.render_frame(METRICS, fleet)
                == obs_top.render_frame(METRICS, fleet))

    def test_frame_surfaces_the_run_state(self):
        frame = obs_top.render_frame(METRICS, None)
        assert "step 40" in frame
        assert "headroom" in frame and "CRITICAL" in frame
        assert "grads fit int16 limbs: NO" in frame
        # no fleet section without a snapshot
        assert "fleet" not in frame.splitlines()

    def test_empty_invocation_says_so(self):
        frame = obs_top.render_frame(None, None)
        assert "nothing to show" in frame


class TestCli:
    def test_once_subprocess_matches_golden(self):
        env = dict(os.environ)
        src = os.path.join(os.path.dirname(__file__), os.pardir, "src")
        env["PYTHONPATH"] = os.pathsep.join(
            p for p in (src, env.get("PYTHONPATH")) if p)
        proc = subprocess.run(
            [sys.executable, "-m", "repro.launch.obs_top",
             "--metrics", METRICS, "--fleet-json", FLEET, "--once"],
            capture_output=True, text=True, env=env, timeout=120)
        assert proc.returncode == 0, proc.stderr
        with open(GOLDEN) as f:
            assert proc.stdout == f.read()
