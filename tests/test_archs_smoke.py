"""Per-architecture smoke tests (deliverable f).

Each assigned architecture instantiates a REDUCED same-family config and
runs one forward/train step + one prefill/decode step on CPU, asserting
output shapes and the absence of NaNs.  Full configs are exercised only
via the dry-run (ShapeDtypeStructs, no allocation).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config, list_archs
from repro.models import lm
from repro.models import transformer as T


def make_batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {"labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.embeds_input:
        batch["embeds"] = jnp.asarray(
            rng.normal(0, 1, (b, s, cfg.d_model)), jnp.float32
        )
        if cfg.mrope_sections:
            batch["positions"] = jnp.broadcast_to(
                jnp.arange(s, dtype=jnp.int32)[None, None], (3, b, s)
            )
    else:
        batch["tokens"] = jnp.asarray(
            rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32
        )
    if cfg.encoder_layers:
        batch["enc_embeds"] = jnp.asarray(
            rng.normal(0, 1, (b, cfg.encoder_seq, cfg.d_model)), jnp.float32
        )
    return batch


ALL_ARCHS = list_archs()


@pytest.mark.parametrize("arch", ALL_ARCHS)
class TestArchSmoke:
    def test_forward_and_train_step(self, arch):
        cfg = get_smoke_config(arch)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        batch = make_batch(cfg)
        (loss, metrics), grads = jax.value_and_grad(
            lm.train_loss, has_aux=True
        )(params, cfg, batch)
        assert jnp.isfinite(loss), f"{arch}: non-finite loss"
        assert float(loss) > 0
        # one SGD step must produce finite params (the 'train step')
        new_params = jax.tree_util.tree_map(lambda p, g: p - 1e-3 * g, params, grads)
        for leaf in jax.tree_util.tree_leaves(new_params):
            assert jnp.all(jnp.isfinite(leaf.astype(jnp.float32)))
        # gradient actually flows into the stack
        gsum = sum(
            float(jnp.sum(jnp.abs(g)))
            for g in jax.tree_util.tree_leaves(grads["scan"])
        )
        assert gsum > 0, f"{arch}: zero gradient in stack"

    def test_prefill_decode_shapes_no_nan(self, arch):
        cfg = get_smoke_config(arch)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        b, s = 2, 32
        batch = make_batch(cfg, b=b, s=s)
        cache = T.init_cache(cfg, batch=b, max_seq=64)
        enc_out = None
        if cfg.encoder_layers:
            enc_out = lm.run_encoder(params, cfg, batch["enc_embeds"])
        logits, cache = lm.prefill(params, cfg, batch, cache)
        assert logits.shape == (b, cfg.vocab_size)
        assert jnp.all(jnp.isfinite(logits))
        tok = jnp.argmax(logits, -1).astype(jnp.int32)
        for _ in range(3):
            logits, cache = lm.decode_step(params, cfg, tok, cache, enc_out=enc_out)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
        assert logits.shape == (b, cfg.vocab_size)
        assert jnp.all(jnp.isfinite(logits))
        assert int(cache["t"]) == s + 3

    def test_full_config_matches_assignment(self, arch):
        """The full config must carry the exact published numbers."""
        spec = {
            "qwen3-32b": (64, 5120, 64, 8, 25600, 151936),
            "llama3.2-1b": (16, 2048, 32, 8, 8192, 128256),
            "starcoder2-7b": (32, 4608, 36, 4, 18432, 49152),
            "h2o-danube-1.8b": (24, 2560, 32, 8, 6912, 32000),
            "rwkv6-3b": (32, 2560, 40, 40, 8960, 65536),
            "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
            "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
            "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
            "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
            "qwen2-vl-72b": (80, 8192, 64, 8, 29568, 152064),
        }[arch]
        cfg = get_config(arch)
        got = (
            cfg.num_layers, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
            cfg.d_ff, cfg.vocab_size,
        )
        assert got == spec
        # layer layout covers exactly num_layers
        assert len(cfg.scan_unit) * cfg.scan_repeats + len(cfg.tail) == cfg.num_layers


class TestArchSpecifics:
    def test_moe_specs(self):
        olmoe = get_config("olmoe-1b-7b")
        assert olmoe.moe.num_experts == 64 and olmoe.moe.top_k == 8
        mix = get_config("mixtral-8x22b")
        assert mix.moe.num_experts == 8 and mix.moe.top_k == 2
        assert mix.sliding_window is not None  # SWA per assignment

    def test_param_counts_in_expected_range(self):
        """Sanity: parameter counts land near the advertised sizes."""
        for arch, lo, hi in [
            ("qwen3-32b", 25e9, 40e9),
            ("llama3.2-1b", 0.9e9, 1.8e9),
            ("starcoder2-7b", 6e9, 9e9),
            ("h2o-danube-1.8b", 1.3e9, 2.4e9),
            ("rwkv6-3b", 2e9, 4e9),
            ("recurrentgemma-9b", 6.5e9, 12e9),
            ("olmoe-1b-7b", 5e9, 8.5e9),
            ("mixtral-8x22b", 120e9, 160e9),
            ("qwen2-vl-72b", 60e9, 85e9),
        ]:
            n = get_config(arch).param_count()
            assert lo < n < hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"

    def test_olmoe_active_params_below_total(self):
        cfg = get_config("olmoe-1b-7b")
        assert cfg.active_param_count() < 0.45 * cfg.param_count()

    def test_les_groups_mode_runs(self):
        """The paper's LES algorithm applied to an LM (technique hook)."""
        from dataclasses import replace

        cfg = replace(get_smoke_config("llama3.2-1b"), num_layers=4, les_groups=2)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        batch = make_batch(cfg)
        (loss, metrics), grads = jax.value_and_grad(
            lm.train_loss, has_aux=True
        )(params, cfg, batch)
        assert "les" in metrics and jnp.isfinite(loss)

    def test_int8_matmul_mode_runs(self):
        """NITRO int8 numerics on LM matmuls (technique hook)."""
        from dataclasses import replace

        cfg = replace(get_smoke_config("qwen3-32b"), int8_matmul=True)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        batch = make_batch(cfg)
        loss, _ = lm.train_loss(params, cfg, batch)
        assert jnp.isfinite(loss)
