"""System tests for the NITRO-D learning algorithm (integer-only LES)."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _gradcheck import assert_bitwise_equal, assert_jaxpr_integer_only
from repro.core import les, model
from repro.core.blocks import BlockSpec
from repro.core.model import NitroConfig
from repro.data import synthetic


def tiny_cnn_cfg(**kw):
    return NitroConfig(
        blocks=(
            BlockSpec("conv", 16, pool=True, d_lr=256),
            BlockSpec("linear", 64),
        ),
        input_shape=(8, 8, 3),
        num_classes=10,
        gamma_inv=512,
        **kw,
    )


@pytest.fixture(scope="module")
def toy_data():
    rng = np.random.default_rng(0)
    templates = rng.integers(-60, 61, (10, 8, 8, 3))
    y = rng.integers(0, 10, 256).astype(np.int32)
    x = np.clip(templates[y] + rng.integers(-40, 41, (256, 8, 8, 3)), -127, 127)
    return jnp.asarray(x.astype(np.int32)), jnp.asarray(y)


class TestTrainStep:
    @pytest.mark.parametrize("fused,fuse_bwd,backend", [
        (True, True, "auto"),       # the default train path (fwd + bwd fused)
        (True, True, "interpret"),  # the actual Pallas kernel bodies, off-TPU
        (True, False, "auto"),      # unfused δ path escape hatch
        (False, False, "auto"),     # fully unfused reference composition
    ])
    def test_step_is_integer_only(self, toy_data, fused, fuse_bwd, backend):
        """No float dtype anywhere in the jit-compiled training step —
        fused forward *and* fused backward (including inside the Pallas
        kernel jaxprs), plus both unfused escape hatches."""
        cfg = NitroConfig(
            blocks=(BlockSpec("conv", 16, pool=True, d_lr=256, dropout=0.1),
                    BlockSpec("linear", 64, dropout=0.1)),
            input_shape=(8, 8, 3), num_classes=10, gamma_inv=512,
            eta_fw=12000, eta_lr=3000,
        )
        x, y = toy_data
        st = les.create_train_state(jax.random.PRNGKey(0), cfg)
        jaxpr = jax.make_jaxpr(
            functools.partial(les.train_step, cfg=cfg, fused=fused,
                              fuse_bwd=fuse_bwd, backend=backend)
        )(st, x=x[:8], labels=y[:8], key=jax.random.PRNGKey(1))
        assert_jaxpr_integer_only(jaxpr.jaxpr)

    def test_loss_decreases_on_learnable_task(self, toy_data):
        x, y = toy_data
        cfg = tiny_cnn_cfg(eta_fw=20000, eta_lr=5000)
        st = les.create_train_state(jax.random.PRNGKey(0), cfg)
        step = jax.jit(functools.partial(les.train_step, cfg=cfg))
        first = None
        for i in range(120):
            st, m = step(st, x=x[:64], labels=y[:64], key=jax.random.PRNGKey(i))
            if first is None:
                first = int(m.local_losses[0])
        # block-0's local loss must fall well below its starting value
        assert int(m.local_losses[0]) < 0.7 * first
        assert int(m.correct) > 6  # above 10% chance on 64 samples

    def test_weights_stay_int16(self, toy_data):
        """Paper §E.3: trained weights fit int16."""
        x, y = toy_data
        cfg = tiny_cnn_cfg(eta_fw=20000, eta_lr=5000)
        st = les.create_train_state(jax.random.PRNGKey(0), cfg)
        step = jax.jit(functools.partial(les.train_step, cfg=cfg))
        for i in range(60):
            st, _ = step(st, x=x[:64], labels=y[:64], key=jax.random.PRNGKey(i))
        mx = max(int(jnp.abs(p).max()) for p in jax.tree_util.tree_leaves(st.params))
        assert mx < 2**15

    def test_activations_stay_int8(self, toy_data):
        x, y = toy_data
        cfg = tiny_cnn_cfg()
        st = les.create_train_state(jax.random.PRNGKey(0), cfg)
        _, acts, _, _ = model.forward(st.params, cfg, x[:32], train=False)
        for a in acts:
            assert int(jnp.abs(a).max()) <= 127

    def test_block_gradient_confinement(self, toy_data):
        """LES property: block-0's update is independent of block-1's and
        the output layer's parameters (gradients never cross blocks)."""
        x, y = toy_data
        cfg = tiny_cnn_cfg()
        st = les.create_train_state(jax.random.PRNGKey(0), cfg)
        step = jax.jit(functools.partial(les.train_step, cfg=cfg))
        st_a, _ = step(st, x=x[:32], labels=y[:32], key=jax.random.PRNGKey(5))

        # perturb downstream params; block-0 update must not change
        mutated = jax.tree_util.tree_map(lambda p: p, st.params)
        mutated["blocks"][1]["fw"]["w"] = mutated["blocks"][1]["fw"]["w"] + 3
        mutated["output"]["w"] = mutated["output"]["w"] - 7
        st_b, _ = step(
            st._replace(params=mutated), x=x[:32], labels=y[:32],
            key=jax.random.PRNGKey(5),
        )
        assert_bitwise_equal(
            st_a.params["blocks"][0], st_b.params["blocks"][0]
        )

    def test_eval_step_counts_correct(self, toy_data):
        x, y = toy_data
        cfg = tiny_cnn_cfg()
        st = les.create_train_state(jax.random.PRNGKey(0), cfg)
        correct = les.eval_step(st, cfg, x[:50], y[:50])
        assert 0 <= int(correct) <= 50

    def test_lr_plateau_schedule(self):
        cfg = tiny_cnn_cfg()
        st = les.create_train_state(jax.random.PRNGKey(0), cfg)
        g0 = int(st.opt_lr.gamma_inv)
        st = les.reduce_lr_on_plateau(st, True)
        assert int(st.opt_lr.gamma_inv) == 3 * g0
        assert int(st.opt_fw.gamma_inv) == 3 * g0 * 640  # AF = 2^6·10


class TestMLPPath:
    def test_mlp_trains(self):
        """MLP-1-like architecture (paper Table 4) on flattened data."""
        ds = synthetic.make_image_dataset("digits28", n_train=256, n_test=64)
        ds = synthetic.flatten_for_mlp(ds)
        cfg = NitroConfig(
            blocks=(BlockSpec("linear", 100), BlockSpec("linear", 50)),
            input_shape=ds.input_shape, num_classes=10,
            gamma_inv=512, eta_fw=12000, eta_lr=3000,
        )
        st = les.create_train_state(jax.random.PRNGKey(0), cfg)
        step = jax.jit(functools.partial(les.train_step, cfg=cfg))
        x = jnp.asarray(ds.x_train[:64])
        y = jnp.asarray(ds.y_train[:64])
        first = None
        for i in range(200):
            st, m = step(st, x=x, labels=y, key=jax.random.PRNGKey(i))
            if first is None:
                first = int(m.local_losses[0])
        assert int(m.local_losses[0]) < 0.8 * first  # block-0 is learning
        assert int(m.correct) > 10  # above 10 % chance (6.4 expected)
