"""Streaming implicit-im2col conv: kernel/oracle/dispatcher parity.

The tentpole guarantee: retiring the HBM patch matrix changes *nothing*
numerically.  Every (conv_mode, backend) combination — the Pallas
streaming kernel (interpret mode off-TPU), the pure-jnp row-band oracle,
and the materialised im2col escape hatch — produces bit-identical
activations, caches, gradients, plan logits and post-step parameters,
over both paper CNN configs, K ∈ {3, 5}, odd H/W edges, pooled and
unpooled blocks.  On top of parity, the streaming path is held to its
defining structural property: no (N·H·W, K²·C) patch matrix appears in
the traced program.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _gradcheck import (
    assert_bitwise_equal,
    assert_jaxpr_integer_only,
    collect_aval_shapes,
)
from repro.configs import paper
from repro.core import activations, layers, les, model as M, scaling
from repro.core.blocks import BlockSpec
from repro.core.model import NitroConfig
from repro.core.scaling import conv_scale_factor
from repro.kernels.nitro_conv import (
    conv_grad_w,
    conv_grad_x,
    fused_conv,
    fused_conv_fwd,
    resolve_conv_mode,
    stream_conv,
    stream_conv_fwd,
    stream_conv_fwd_ref,
    stream_conv_grad_w,
    stream_conv_grad_w_ref,
    stream_conv_grad_x_ref,
    stream_conv_ref,
)
from repro.kernels.nitro_matmul.ops import check_alpha_inv, fused_matmul


def _rand_case(n, h, w_sp, c, f, k, seed=0, dtype=jnp.int32):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-127, 128, (n, h, w_sp, c)), dtype)
    w = jnp.asarray(rng.integers(-40, 41, (k, k, c, f)), dtype)
    return x, w


def _materialised_conv(x, w, *, sf, alpha_inv=10, apply_relu=True,
                       pool=False, out_dtype=jnp.int32):
    """Independent oracle: explicit im2col conv → scale → relu → pool,
    composed from the repro.core reference ops."""
    z, _ = layers.conv_forward({"w": w.astype(jnp.int32)}, x.astype(jnp.int32))
    a = scaling.scale_forward(z, sf)
    if apply_relu:
        a = activations.nitro_relu(a, alpha_inv)
    if pool:
        a = jnp.max(layers.window_view_2x2(a), axis=3)
    return a.astype(out_dtype)


# shape sweep: tile-aligned, odd H/W edges, degenerate smalls, H < band
SHAPES = [
    (2, 8, 8, 3, 8),      # even, multi-band
    (1, 5, 7, 2, 4),      # odd H and W
    (2, 7, 5, 3, 8),      # odd the other way
    (3, 16, 4, 4, 8),     # narrow W
    (1, 1, 1, 1, 1),      # degenerate single pixel (no pool)
    (2, 9, 9, 2, 130),    # F past one filter tile
]


class TestStreamOracle:
    """Pure-jnp row-band oracle vs the materialised reference composition."""

    @pytest.mark.parametrize("k", [3, 5])
    @pytest.mark.parametrize("n,h,w_sp,c,f", SHAPES)
    def test_shape_sweep(self, n, h, w_sp, c, f, k):
        x, w = _rand_case(n, h, w_sp, c, f, k, seed=h * 10 + w_sp)
        sf = conv_scale_factor(k, c)
        got = stream_conv_ref(x, w, sf=sf)
        want = _materialised_conv(x, w, sf=sf)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("k", [3, 5])
    @pytest.mark.parametrize("n,h,w_sp,c,f", [s for s in SHAPES if s[1] > 1])
    def test_pool_epilogue(self, n, h, w_sp, c, f, k):
        """Fused 2×2 pool ≡ separate pool pass, incl. odd-edge cropping."""
        x, w = _rand_case(n, h, w_sp, c, f, k, seed=h + w_sp)
        sf = conv_scale_factor(k, c)
        got = stream_conv_ref(x, w, sf=sf, pool=True)
        want = _materialised_conv(x, w, sf=sf, pool=True)
        assert got.shape == (n, h // 2, w_sp // 2, f)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("bh", [1, 2, 3, 8, 64])
    def test_band_size_invariance(self, bh):
        """The result must not depend on the streaming granularity."""
        x, w = _rand_case(2, 10, 6, 3, 8, 3, seed=bh)
        sf = conv_scale_factor(3, 3)
        got = stream_conv_ref(x, w, sf=sf, pool=True, bh=bh)
        want = _materialised_conv(x, w, sf=sf, pool=True)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_fwd_two_output_contract(self):
        x, w = _rand_case(2, 9, 7, 3, 8, 3, seed=1)
        sf = conv_scale_factor(3, 3)
        a, z_star = stream_conv_fwd_ref(x, w, sf=sf)
        z, _ = layers.conv_forward({"w": w}, x)
        z_star_want = scaling.scale_forward(z, sf)
        np.testing.assert_array_equal(np.asarray(z_star), np.asarray(z_star_want))
        np.testing.assert_array_equal(
            np.asarray(a), np.asarray(activations.nitro_relu(z_star_want, 10))
        )
        assert z_star.dtype == jnp.int32

    @pytest.mark.parametrize("k", [3, 5])
    def test_gradients_match_materialised(self, k):
        x, w = _rand_case(2, 6, 5, 3, 4, k, seed=k)
        rng = np.random.default_rng(7)
        g = jnp.asarray(rng.integers(-63, 64, (2, 6, 5, 4)), jnp.int32)
        gw = stream_conv_grad_w_ref(x, g, kernel_size=k)
        gx = stream_conv_grad_x_ref(g, w)
        gx_want, grads_want = layers.conv_backward(
            {"w": w}, layers.ConvCache(x=x), g, conv_mode="materialise"
        )
        assert_bitwise_equal(gw, grads_want["w"])
        assert_bitwise_equal(gx, gx_want)


class TestStreamKernel:
    """The Pallas kernel (interpret mode) vs the jnp streaming oracle."""

    @pytest.mark.parametrize("k", [3, 5])
    @pytest.mark.parametrize("n,h,w_sp,c,f", SHAPES)
    def test_shape_sweep(self, n, h, w_sp, c, f, k):
        x, w = _rand_case(n, h, w_sp, c, f, k, seed=n + h)
        sf = conv_scale_factor(k, c)
        got = stream_conv(x, w, sf=sf, interpret=True)
        want = stream_conv_ref(x, w, sf=sf)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("pool", [False, True])
    @pytest.mark.parametrize("apply_relu", [False, True])
    def test_epilogue_variants(self, pool, apply_relu):
        x, w = _rand_case(2, 6, 6, 3, 8, 3, seed=3)
        sf = conv_scale_factor(3, 3)
        got = stream_conv(
            x, w, sf=sf, apply_relu=apply_relu, pool=pool, interpret=True
        )
        want = _materialised_conv(x, w, sf=sf, apply_relu=apply_relu, pool=pool)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("bh,bf", [(2, 4), (3, 8), (8, 128)])
    def test_tile_size_sweep(self, bh, bf):
        """Result must be invariant to band height and filter tiling."""
        x, w = _rand_case(2, 7, 6, 3, 12, 3, seed=bh * 10 + bf)
        sf = conv_scale_factor(3, 3)
        got = stream_conv(x, w, sf=sf, bh=bh, bf=bf, interpret=True)
        want = stream_conv_ref(x, w, sf=sf)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_int8_operands(self):
        """The plan feeds int8 activations; row DMA + patches must cope."""
        x, w = _rand_case(2, 8, 8, 4, 8, 3, seed=5, dtype=jnp.int8)
        sf = conv_scale_factor(3, 4)
        got = stream_conv(x, w, sf=sf, pool=True, out_dtype=jnp.int8,
                          interpret=True)
        want = stream_conv_ref(x, w, sf=sf, pool=True, out_dtype=jnp.int8)
        assert got.dtype == jnp.int8
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_fwd_two_outputs(self):
        x, w = _rand_case(2, 9, 5, 3, 8, 3, seed=6)
        sf = conv_scale_factor(3, 3)
        a_k, z_k = stream_conv_fwd(x, w, sf=sf, interpret=True, bh=4, bf=4)
        a_r, z_r = stream_conv_fwd_ref(x, w, sf=sf)
        np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_r))
        np.testing.assert_array_equal(np.asarray(z_k), np.asarray(z_r))
        assert z_k.dtype == jnp.int32

    @pytest.mark.parametrize("k,bf", [(3, 128), (5, 2), (3, 4)])
    def test_grad_w_kernel(self, k, bf):
        """VMEM-accumulated grad_w ≡ materialised im2colᵀ @ g."""
        x, w = _rand_case(3, 6, 5, 2, 6, k, seed=k)
        rng = np.random.default_rng(8)
        g = jnp.asarray(rng.integers(-63, 64, (3, 6, 5, 6)), jnp.int32)
        got = stream_conv_grad_w(x, g, kernel_size=k, bf=bf, interpret=True)
        want = stream_conv_grad_w_ref(x, g, kernel_size=k)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestDispatcher:
    """conv_mode/backend dispatch + the alpha_inv validation satellite."""

    @pytest.mark.parametrize("pool", [False, True])
    def test_all_routes_agree(self, pool):
        x, w = _rand_case(2, 6, 6, 3, 8, 3, seed=9)
        sf = conv_scale_factor(3, 3)
        outs = {}
        for mode in ("stream", "materialise"):
            for backend in ("reference", "interpret"):
                outs[(mode, backend)] = fused_conv(
                    x, w, sf=sf, pool=pool, backend=backend, conv_mode=mode
                )
        first = next(iter(outs.values()))
        for key, out in outs.items():
            assert_bitwise_equal(out, first, err_msg=str(key))

    def test_fwd_routes_agree(self):
        x, w = _rand_case(2, 7, 7, 3, 8, 3, seed=10)
        sf = conv_scale_factor(3, 3)
        ref = fused_conv_fwd(x, w, sf=sf, backend="reference",
                             conv_mode="stream")
        for mode, backend in [("stream", "interpret"),
                              ("materialise", "reference")]:
            got = fused_conv_fwd(x, w, sf=sf, backend=backend, conv_mode=mode)
            assert_bitwise_equal(got, ref, err_msg=f"{mode}/{backend}")

    def test_grad_routes_agree(self):
        x, w = _rand_case(2, 6, 6, 3, 4, 3, seed=11)
        rng = np.random.default_rng(11)
        g = jnp.asarray(rng.integers(-63, 64, (2, 6, 6, 4)), jnp.int32)
        ref_w = conv_grad_w(x, g, kernel_size=3, backend="reference",
                            conv_mode="materialise")
        ref_x = conv_grad_x(g, w, backend="reference", conv_mode="materialise")
        for mode, backend in [("stream", "reference"), ("stream", "interpret")]:
            assert_bitwise_equal(
                conv_grad_w(x, g, kernel_size=3, backend=backend,
                            conv_mode=mode),
                ref_w, err_msg=f"{mode}/{backend}")
            assert_bitwise_equal(
                conv_grad_x(g, w, backend=backend, conv_mode=mode),
                ref_x, err_msg=f"{mode}/{backend}")

    def test_unknown_conv_mode_raises(self):
        with pytest.raises(ValueError, match="unknown conv_mode"):
            resolve_conv_mode("fuse-everything")
        x, w = _rand_case(1, 4, 4, 2, 2, 3)
        with pytest.raises(ValueError, match="unknown conv_mode"):
            fused_conv(x, w, sf=8, conv_mode="材料")

    def test_even_kernel_rejected_on_stream(self):
        x = jnp.zeros((1, 4, 4, 2), jnp.int32)
        w = jnp.zeros((2, 2, 2, 2), jnp.int32)
        with pytest.raises(ValueError, match="odd kernel"):
            stream_conv_ref(x, w, sf=8)

    def test_alpha_inv_zero_raises(self):
        """Satellite: alpha_inv=0 must raise, not silently become 1."""
        x, w = _rand_case(1, 4, 4, 2, 2, 3)
        with pytest.raises(ValueError, match="alpha_inv"):
            fused_conv(x, w, sf=8, alpha_inv=0)
        x2 = jnp.zeros((4, 8), jnp.int32)
        w2 = jnp.zeros((8, 4), jnp.int32)
        with pytest.raises(ValueError, match="alpha_inv"):
            fused_matmul(x2, w2, sf=8, alpha_inv=0)

    def test_alpha_inv_ignored_without_relu(self):
        """Frozen no-activation layers export alpha_inv=0: still legal (and
        normalised, so it cannot fan out into extra kernel compilations)."""
        assert check_alpha_inv(0, False) == 1
        assert check_alpha_inv(10, True) == 10
        x2 = jnp.asarray(
            np.random.default_rng(0).integers(-127, 128, (4, 8)), jnp.int32
        )
        w2 = jnp.asarray(
            np.random.default_rng(1).integers(-40, 41, (8, 4)), jnp.int32
        )
        out = fused_matmul(x2, w2, sf=8, alpha_inv=0, apply_relu=False,
                           backend="reference")
        assert out.shape == (4, 4)


class TestTrainingParity:
    """forward_layers / train_step across conv modes on the paper configs."""

    @pytest.mark.parametrize("arch", ["vgg8b", "vgg11b"])
    def test_forward_stream_bit_exact_on_paper_cnn(self, arch):
        cfg = paper.get(arch, scale=0.0625)
        state = les.create_train_state(jax.random.PRNGKey(7), cfg)
        rng = np.random.default_rng(11)
        x = jnp.asarray(
            rng.integers(-127, 128, (4, *cfg.input_shape)), jnp.int32
        )
        outs = {
            mode: M.forward(state.params, cfg, x, train=False, fused=True,
                            conv_mode=mode)
            for mode in ("stream", "materialise")
        }
        unfused = M.forward(state.params, cfg, x, train=False, fused=False)
        for mode, (y, acts, caches, _) in outs.items():
            assert_bitwise_equal(y, unfused[0], err_msg=mode)
            for a_m, a_u, c_m, c_u in zip(acts, unfused[1], caches, unfused[2]):
                assert_bitwise_equal(a_m, a_u, err_msg=mode)
                assert_bitwise_equal(c_m["z_star"], c_u["z_star"],
                                     err_msg=mode)

    @pytest.mark.parametrize("kernel_size", [3, 5])
    def test_k5_block_and_odd_input(self, kernel_size):
        """K=5 and odd 9×9 spatial dims through a pooled conv block."""
        cfg = NitroConfig(
            blocks=(BlockSpec("conv", 12, pool=True, d_lr=128,
                              kernel_size=kernel_size),
                    BlockSpec("linear", 32)),
            input_shape=(9, 9, 3), num_classes=10, gamma_inv=512,
        )
        state = les.create_train_state(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.integers(-127, 128, (3, 9, 9, 3)), jnp.int32)
        y_s, _, c_s, _ = M.forward(state.params, cfg, x, conv_mode="stream")
        y_m, _, c_m, _ = M.forward(state.params, cfg, x,
                                   conv_mode="materialise")
        y_u, _, _, _ = M.forward(state.params, cfg, x, fused=False)
        np.testing.assert_array_equal(np.asarray(y_s), np.asarray(y_m))
        np.testing.assert_array_equal(np.asarray(y_s), np.asarray(y_u))
        np.testing.assert_array_equal(
            np.asarray(c_s[0]["z_star"]), np.asarray(c_m[0]["z_star"])
        )

    def test_train_step_stream_bit_exact(self):
        cfg = paper.get("vgg8b", scale=0.0625)
        st = les.create_train_state(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(4)
        x = jnp.asarray(
            rng.integers(-127, 128, (8, *cfg.input_shape)), jnp.int32
        )
        y = jnp.asarray(rng.integers(0, cfg.num_classes, 8), jnp.int32)
        key = jax.random.PRNGKey(9)
        stepped = {
            mode: jax.jit(functools.partial(
                les.train_step, cfg=cfg, conv_mode=mode
            ))(st, x=x, labels=y, key=key)
            for mode in ("stream", "materialise")
        }
        assert_bitwise_equal(stepped["stream"][0].params,
                             stepped["materialise"][0].params)
        assert int(stepped["stream"][1].loss) == int(stepped["materialise"][1].loss)

    def test_conv_backward_modes_agree(self):
        x, w = _rand_case(2, 8, 6, 3, 8, 3, seed=12)
        rng = np.random.default_rng(12)
        g = jnp.asarray(rng.integers(-63, 64, (2, 8, 6, 8)), jnp.int32)
        cache = layers.ConvCache(x=x)
        stream = layers.conv_backward({"w": w}, cache, g, conv_mode="stream")
        materialise = layers.conv_backward({"w": w}, cache, g,
                                           conv_mode="materialise")
        assert_bitwise_equal(stream, materialise)


# ---------------------------------------------------------------------------
# Structural property: the streaming path has no HBM patch matrix
# (jaxpr-walking helpers live in the shared harness, tests/_gradcheck.py)
# ---------------------------------------------------------------------------


class TestStructural:
    @staticmethod
    def _patch_shapes(n, h, w_sp, c, k):
        """The forms the materialised patch matrix takes in a traced
        program: the 2-D matmul operand and its pre-reshape 4-D layout."""
        return {(n * h * w_sp, k * k * c), (n, h, w_sp, k * k * c)}

    @pytest.mark.parametrize("backend", ["reference", "interpret"])
    def test_no_patch_matrix_in_stream_fwd(self, backend):
        """Acceptance criterion: the (N·H·W, K²·C) patch matrix must not
        appear anywhere in the streaming program — including inside the
        Pallas kernel body — while the materialised path (sanity check)
        does produce it."""
        n, h, w_sp, c, f, k = 4, 16, 16, 8, 8, 3
        x, w = _rand_case(n, h, w_sp, c, f, k, seed=0)
        sf = conv_scale_factor(k, c)
        patch_shapes = self._patch_shapes(n, h, w_sp, c, k)

        def trace(mode):
            jaxpr = jax.make_jaxpr(functools.partial(
                fused_conv, sf=sf, backend=backend, conv_mode=mode
            ))(x, w)
            return collect_aval_shapes(jaxpr.jaxpr)

        assert not (patch_shapes & trace("stream")), (
            "streaming path materialised a full-size patch matrix"
        )
        assert patch_shapes & trace("materialise"), (
            "sanity: materialised path should contain the patch matrix"
        )

    def test_no_patch_matrix_in_stream_plan(self):
        """Same property end-to-end through a compiled multi-layer plan:
        none of the per-layer (N·Hℓ·Wℓ, K²·Cℓ) full patch sizes may appear
        in the streaming program, while the materialised one (sanity)
        contains every one of them."""
        from repro.infer.export import freeze
        from repro.infer.plan import _execute, compile_plan

        cfg = paper.get("vgg8b", scale=0.0625)
        state = les.create_train_state(jax.random.PRNGKey(0), cfg)
        fm = freeze(state, cfg)
        n = 4
        x = jnp.zeros((n, *cfg.input_shape), jnp.int32)

        # full patch-matrix shapes of every conv layer, tracking geometry
        h, w_sp, c = cfg.input_shape
        patch_shapes = set()
        flat_patches = set()  # the 2-D matmul-operand form only
        for spec in cfg.blocks:
            if spec.kind != "conv":
                break
            patch_shapes |= self._patch_shapes(n, h, w_sp, c, spec.kernel_size)
            flat_patches.add((n * h * w_sp, spec.kernel_size ** 2 * c))
            c = spec.out_features
            if spec.pool:
                h, w_sp = h // 2, w_sp // 2

        for mode, expect_patch in (("stream", False), ("materialise", True)):
            plan = compile_plan(fm, backend="reference", conv_mode=mode)
            jaxpr = jax.make_jaxpr(functools.partial(
                _execute, metas=plan.metas, backend=plan.backend
            ))(plan.weights, x)
            shapes = collect_aval_shapes(jaxpr.jaxpr)
            if expect_patch:
                assert flat_patches <= shapes, "sanity: patches expected"
            else:
                assert not (patch_shapes & shapes), (
                    "streaming plan materialised a full patch matrix"
                )

    @pytest.mark.parametrize("conv_mode,backend", [
        ("stream", "auto"),        # the default train path
        ("stream", "interpret"),   # the actual Pallas kernel bodies, off-TPU
        ("materialise", "auto"),   # explicit-im2col escape hatch
    ])
    def test_train_step_integer_only(self, conv_mode, backend):
        """No float dtype anywhere in the traced step — descending into the
        streaming conv kernel bodies (fwd + grad_w + grad_x)."""
        cfg = NitroConfig(
            blocks=(BlockSpec("conv", 16, pool=True, d_lr=256, dropout=0.1),
                    BlockSpec("linear", 64, dropout=0.1)),
            input_shape=(8, 8, 3), num_classes=10, gamma_inv=512,
            eta_fw=12000, eta_lr=3000,
        )
        st = les.create_train_state(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.integers(-127, 128, (8, 8, 8, 3)), jnp.int32)
        y = jnp.asarray(rng.integers(0, 10, 8), jnp.int32)
        jaxpr = jax.make_jaxpr(
            functools.partial(les.train_step, cfg=cfg, fused=True,
                              backend=backend, conv_mode=conv_mode)
        )(st, x=x, labels=y, key=jax.random.PRNGKey(1))
        assert_jaxpr_integer_only(jaxpr.jaxpr)


class TestPlanStream:
    @pytest.mark.parametrize("arch", ["vgg8b", "vgg11b"])
    @pytest.mark.parametrize("backend", ["reference", "interpret"])
    def test_plan_parity_on_paper_cnn(self, arch, backend):
        """Streaming plan ≡ materialised plan ≡ frozen_forward oracle."""
        from repro.infer.export import freeze
        from repro.infer.plan import compile_plan

        cfg = paper.get(arch, scale=0.0625)
        state = les.create_train_state(jax.random.PRNGKey(1), cfg)
        fm = freeze(state, cfg)
        rng = np.random.default_rng(3)
        x = jnp.asarray(
            rng.integers(-127, 128, (2, *cfg.input_shape)), jnp.int32
        )
        want = M.frozen_forward(state.params, cfg, x)
        for mode in ("stream", "materialise"):
            plan = compile_plan(fm, backend=backend, conv_mode=mode)
            np.testing.assert_array_equal(
                np.asarray(plan.logits(x)), np.asarray(want),
                err_msg=f"{arch}/{backend}/{mode}",
            )

    def test_step_meta_describes_fusion(self):
        from repro.infer.export import freeze
        from repro.infer.plan import compile_plan

        cfg = paper.get("vgg8b", scale=0.0625)
        state = les.create_train_state(jax.random.PRNGKey(0), cfg)
        fm = freeze(state, cfg)
        plan = compile_plan(fm, backend="reference", conv_mode="stream")
        for meta, spec in zip(plan.metas, cfg.blocks):
            if spec.kind == "conv":
                assert meta.conv_mode == "stream"
                assert meta.fused_pool == spec.pool
                assert meta.kernel_size == spec.kernel_size
            else:
                assert meta.conv_mode == ""
                assert not meta.fused_pool
        mat = compile_plan(fm, backend="reference", conv_mode="materialise")
        assert all(not m.fused_pool for m in mat.metas)

    def test_summary_counts_patch_traffic(self):
        """Satellite: conv rows must account the im2col patch round-trip
        (~2K²·input bytes) in the materialised estimate and report the
        per-layer streaming delta."""
        from repro.infer.export import freeze
        from repro.infer.plan import compile_plan

        cfg = paper.get("vgg8b", scale=0.0625)
        state = les.create_train_state(jax.random.PRNGKey(0), cfg)
        plan = compile_plan(freeze(state, cfg), backend="reference")
        shape = cfg.input_shape
        in_itemsize = 4
        for row, meta in zip(plan.summary(), plan.metas):
            per_sample = row["hbm_per_sample_bytes"]
            if row["kind"] == "conv":
                h, w_sp, c = shape
                k = meta.kernel_size
                in_bytes = h * w_sp * c * in_itemsize
                # materialised estimate includes patch write + read back
                assert per_sample["materialise"] >= 2 * k * k * in_bytes
                assert per_sample["stream"] < per_sample["materialise"]
                assert row["stream_saving_ratio"] > k  # ≈K², conservatively >K
                f = row["weight_shape"][-1]
                shape = (h // 2, w_sp // 2, f) if meta.pool else (h, w_sp, f)
            else:
                assert row["stream_saving_ratio"] == 1.0
                shape = (row["weight_shape"][-1],)
            in_itemsize = jnp.dtype(meta.out_dtype).itemsize

    def test_window_view_public_name(self):
        """Satellite: the pool window helper is public API now."""
        x = jnp.arange(2 * 5 * 7 * 3, dtype=jnp.int32).reshape(2, 5, 7, 3)
        win = layers.window_view_2x2(x)
        assert win.shape == (2, 2, 3, 4, 3)
        out, _ = layers.maxpool_forward(x)
        np.testing.assert_array_equal(
            np.asarray(jnp.max(win, axis=3)), np.asarray(out)
        )
