"""Property-based tests of the NITRO-ReLU paper identities (§3.2).

Hypothesis-driven (through the ``tests/_compat`` shim when the real
package is absent): ``segment_means`` / ``mu_int8`` / ``nitro_relu`` /
``nitro_relu_backward`` must satisfy their defining piecewise formulas
across the ``alpha_inv`` range and int8/int32 carrying dtypes, and
``check_alpha_inv`` must enforce its ValueError contract.

Ground truth is pure-Python integer arithmetic (``//`` is the paper's
⌊·⌋), evaluated elementwise — independent of jnp, so these tests anchor
the jnp ops the kernels in turn anchor to.
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.activations import (
    DEFAULT_ALPHA_INV,
    mu_int8,
    nitro_relu,
    nitro_relu_backward,
    segment_means,
)
from repro.core.numerics import ACT_MAX, ACT_MIN
from repro.kernels.nitro_matmul.ops import check_alpha_inv

alphas = st.integers(1, 127)
z_values = st.integers(-400, 400)      # straddles both saturation knees
grads = st.integers(-(2 ** 15), 2 ** 15)


def _relu_scalar(z: int, alpha_inv: int) -> int:
    """The §3.2 four-segment definition, in pure Python ints."""
    mu = mu_int8(alpha_inv)
    if z < ACT_MIN:
        return ACT_MIN // alpha_inv - mu
    if z < 0:
        return z // alpha_inv - mu
    if z <= ACT_MAX:
        return z - mu
    return ACT_MAX - mu


def _relu_bwd_scalar(z: int, g: int, alpha_inv: int) -> int:
    """Piecewise derivative: 0 / ⌊g/α_inv⌋ / g / 0."""
    if z < ACT_MIN or z > ACT_MAX:
        return 0
    if z < 0:
        return g // alpha_inv
    return g


class TestSegmentMeans:
    @given(alphas)
    @settings(max_examples=40, deadline=None)
    def test_defining_formulas(self, alpha_inv):
        m0, m1, m2, m3 = segment_means(alpha_inv)
        assert m0 == -127 // alpha_inv
        assert m1 == -127 // (2 * alpha_inv)
        assert (m2, m3) == (63, 127)

    @given(alphas)
    @settings(max_examples=40, deadline=None)
    def test_ordering_and_mu(self, alpha_inv):
        """m0 ≤ m1 < 0 < m2 < m3, and μ is their floored integer mean."""
        m = segment_means(alpha_inv)
        assert m[0] <= m[1] < 0 < m[2] < m[3]
        assert mu_int8(alpha_inv) == sum(m) // 4

    def test_default_alpha(self):
        assert mu_int8() == mu_int8(DEFAULT_ALPHA_INV)


class TestNitroReluForward:
    @given(z_values, alphas)
    @settings(max_examples=60, deadline=None)
    def test_matches_piecewise_definition(self, z, alpha_inv):
        got = nitro_relu(jnp.asarray([z], jnp.int32), alpha_inv)
        assert int(got[0]) == _relu_scalar(z, alpha_inv)

    @given(z_values, z_values, alphas)
    @settings(max_examples=60, deadline=None)
    def test_monotone_nondecreasing(self, z1, z2, alpha_inv):
        lo, hi = min(z1, z2), max(z1, z2)
        out = nitro_relu(jnp.asarray([lo, hi], jnp.int32), alpha_inv)
        assert int(out[0]) <= int(out[1])

    @given(z_values, alphas)
    @settings(max_examples=60, deadline=None)
    def test_saturation_clamps(self, z, alpha_inv):
        """Outside [-127, 127] the output equals the knee's output."""
        knee = min(max(z, ACT_MIN), ACT_MAX)
        out = nitro_relu(jnp.asarray([z, knee], jnp.int32), alpha_inv)
        assert int(out[0]) == int(out[1])

    @given(st.integers(-127, 0), st.integers(-127, 0), alphas)
    @settings(max_examples=60, deadline=None)
    def test_leaky_segment_realises_floor_slope(self, z1, z2, alpha_inv):
        """On the leaky segment the forward difference is exactly the
        difference of the floors — the 1/α_inv slope the backward mirrors."""
        out = nitro_relu(jnp.asarray([z1, z2], jnp.int32), alpha_inv)
        assert int(out[0]) - int(out[1]) == z1 // alpha_inv - z2 // alpha_inv

    @given(st.integers(-127, 400), st.integers(2, 127))
    @settings(max_examples=60, deadline=None)
    def test_output_fits_int8_for_alpha_ge_2(self, z, alpha_inv):
        """The int8-activation claim: for α_inv ≥ 2 every output lies in
        [-127, 127].  (α_inv = 1 is the documented edge: μ = −1 pushes the
        positive saturation to 128.)"""
        out = int(nitro_relu(jnp.asarray([z], jnp.int32), alpha_inv)[0])
        assert -127 <= out <= 127

    @given(st.integers(-127, 127), st.integers(2, 127))
    @settings(max_examples=60, deadline=None)
    def test_int8_dtype_agrees_with_int32(self, z, alpha_inv):
        """Computing in int8 ≡ computing in int32 then narrowing, wherever
        the result fits int8 (which test_output_fits_int8 guarantees)."""
        got8 = nitro_relu(jnp.asarray([z], jnp.int8), alpha_inv)
        got32 = nitro_relu(jnp.asarray([z], jnp.int32), alpha_inv)
        assert got8.dtype == jnp.int8
        assert int(got8[0]) == int(got32[0])


class TestNitroReluBackward:
    @given(z_values, grads, alphas)
    @settings(max_examples=60, deadline=None)
    def test_matches_piecewise_definition(self, z, g, alpha_inv):
        got = nitro_relu_backward(
            jnp.asarray([z], jnp.int32), jnp.asarray([g], jnp.int32), alpha_inv
        )
        assert int(got[0]) == _relu_bwd_scalar(z, g, alpha_inv)

    @given(z_values, alphas)
    @settings(max_examples=40, deadline=None)
    def test_zero_gradient_maps_to_zero(self, z, alpha_inv):
        got = nitro_relu_backward(
            jnp.asarray([z], jnp.int32), jnp.zeros((1,), jnp.int32), alpha_inv
        )
        assert int(got[0]) == 0

    @given(st.integers(1, 2 ** 10), alphas)
    @settings(max_examples=40, deadline=None)
    def test_saturated_segments_block_gradient(self, g, alpha_inv):
        z = jnp.asarray([ACT_MIN - 1, ACT_MAX + 1, -1000, 1000], jnp.int32)
        got = nitro_relu_backward(z, jnp.full((4,), g, jnp.int32), alpha_inv)
        np.testing.assert_array_equal(np.asarray(got), np.zeros(4))

    @given(st.integers(0, 127), grads, alphas)
    @settings(max_examples=40, deadline=None)
    def test_identity_segment_passes_gradient(self, z, g, alpha_inv):
        got = nitro_relu_backward(
            jnp.asarray([z], jnp.int32), jnp.asarray([g], jnp.int32), alpha_inv
        )
        assert int(got[0]) == g

    @given(st.integers(-127, -1), grads, alphas)
    @settings(max_examples=40, deadline=None)
    def test_leaky_segment_floors_like_the_forward(self, z, g, alpha_inv):
        """The backward's ⌊g/α_inv⌋ is the same floor the forward slope
        realises — the chain-rule consistency the fused prologue relies on."""
        got = nitro_relu_backward(
            jnp.asarray([z], jnp.int32), jnp.asarray([g], jnp.int32), alpha_inv
        )
        assert int(got[0]) == g // alpha_inv

    @given(st.integers(-127, 127), st.integers(-127, 127), alphas)
    @settings(max_examples=40, deadline=None)
    def test_int8_dtype_agrees_with_int32(self, z, g, alpha_inv):
        """int8 z*/g inputs ≡ the int32 computation narrowed (the result
        ⌊g/α⌋ or g or 0 always fits int8 when g does)."""
        got8 = nitro_relu_backward(
            jnp.asarray([z], jnp.int8), jnp.asarray([g], jnp.int8), alpha_inv
        )
        got32 = nitro_relu_backward(
            jnp.asarray([z], jnp.int32), jnp.asarray([g], jnp.int32), alpha_inv
        )
        assert got8.dtype == jnp.int8
        assert int(got8[0]) == int(got32[0])


class TestCheckAlphaInv:
    @given(st.integers(-127, 0))
    @settings(max_examples=30, deadline=None)
    def test_nonpositive_raises_with_relu(self, bad):
        with pytest.raises(ValueError, match="alpha_inv"):
            check_alpha_inv(bad, True)

    @given(st.integers(-127, 127))
    @settings(max_examples=30, deadline=None)
    def test_normalised_to_one_without_relu(self, any_value):
        """apply_relu=False: the value is unused and normalised, so frozen
        no-activation layers can carry alpha_inv=0 without recompiles."""
        assert check_alpha_inv(any_value, False) == 1

    @given(alphas)
    @settings(max_examples=30, deadline=None)
    def test_positive_passes_through_as_int(self, alpha_inv):
        out = check_alpha_inv(alpha_inv, True)
        assert out == alpha_inv and isinstance(out, int)

    def test_float_input_rejected_by_contract(self):
        """Activations reject float tensors outright (integer-only)."""
        with pytest.raises(TypeError, match="integer"):
            nitro_relu(jnp.zeros((2,), jnp.float32))
        with pytest.raises(TypeError, match="integer"):
            nitro_relu_backward(
                jnp.zeros((2,), jnp.int32), jnp.zeros((2,), jnp.float32)
            )
