"""Deterministic fallback for the ``hypothesis`` decorators.

Activated by ``tests/conftest.py`` only when the real package is missing.
Implements exactly the API surface this test-suite uses — ``given``,
``settings``, and the strategies in ``hypothesis.strategies`` — with a
seeded PRNG per test (stable across runs) and boundary-biased integer
draws.  No shrinking, no database, no deadlines: a failing example is
reported with the drawn values in the assertion context.

``NITRO_HYPOTHESIS_MAX_EXAMPLES`` caps per-test example counts (the real
package amortises far more examples than a CI container should pay for).
"""

from __future__ import annotations

import functools
import os
import random
import zlib

from . import strategies

__all__ = ["given", "settings", "assume", "HealthCheck", "strategies"]

_MAX_EXAMPLES_CAP = int(os.environ.get("NITRO_HYPOTHESIS_MAX_EXAMPLES", "50"))


class HealthCheck:
    """API-compatibility stub (health checks are meaningless here)."""

    too_slow = "too_slow"
    data_too_large = "data_too_large"
    filter_too_much = "filter_too_much"


class _Assumption(Exception):
    pass


def assume(condition) -> bool:
    """Reject the current example (the runner draws a replacement)."""
    if not condition:
        raise _Assumption()
    return True


class settings:
    """Decorator storing run parameters; composes with ``given`` in either
    order (the real package allows both)."""

    def __init__(self, max_examples: int = 20, deadline=None, **_ignored):
        self.max_examples = max_examples
        self.deadline = deadline

    def __call__(self, fn):
        fn._shim_settings = self
        return fn


def given(*given_strategies, **given_kw):
    """Run the test once per drawn example, deterministically seeded."""

    def decorate(fn):
        @functools.wraps(fn)
        def wrapper(*args, **kwargs):
            cfg = (
                getattr(wrapper, "_shim_settings", None)
                or getattr(fn, "_shim_settings", None)
                or settings()
            )
            n = min(cfg.max_examples, _MAX_EXAMPLES_CAP)
            # stable per-test seed: same examples on every run/machine
            seed = zlib.crc32(fn.__qualname__.encode())
            rng = random.Random(seed)
            ran = 0
            attempts = 0
            while ran < n and attempts < n * 20:
                attempts += 1
                try:
                    drawn = [s.example(rng) for s in given_strategies]
                    drawn_kw = {k: s.example(rng) for k, s in given_kw.items()}
                except _Assumption:
                    continue
                try:
                    fn(*args, *drawn, **kwargs, **drawn_kw)
                except _Assumption:
                    continue
                except Exception as e:
                    raise AssertionError(
                        f"falsifying example (shim, draw {ran}): "
                        f"args={drawn!r} kwargs={drawn_kw!r}"
                    ) from e
                ran += 1

        # pytest resolves fixtures from the *original* signature via
        # ``__wrapped__``; drop it so the drawn parameters aren't mistaken
        # for fixtures.
        del wrapper.__wrapped__
        return wrapper

    return decorate
