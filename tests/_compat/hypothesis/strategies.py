"""Strategy objects for the hypothesis fallback shim.

Each strategy exposes ``example(rng) -> value``.  Integer draws are
boundary-biased (min, max, 0, ±1 with elevated probability) — most of the
bugs property tests catch in integer arithmetic live on the boundaries,
and a uniform draw over ``[-2^24, 2^24]`` would essentially never hit
them.
"""

from __future__ import annotations

import math
import random
from typing import Any, Callable, Sequence


class SearchStrategy:
    def __init__(self, draw_fn: Callable[[random.Random], Any]):
        self._draw_fn = draw_fn

    def example(self, rng: random.Random) -> Any:
        return self._draw_fn(rng)


def integers(min_value: int, max_value: int) -> SearchStrategy:
    corpus = [v for v in (min_value, max_value, 0, 1, -1, min_value + 1, max_value - 1)
              if min_value <= v <= max_value]

    def draw(rng: random.Random) -> int:
        if corpus and rng.random() < 0.2:
            return rng.choice(corpus)
        return rng.randint(min_value, max_value)

    return SearchStrategy(draw)


def floats(min_value: float, max_value: float, **_ignored) -> SearchStrategy:
    def draw(rng: random.Random) -> float:
        if rng.random() < 0.15:
            return rng.choice([min_value, max_value])
        if min_value > 0 and max_value / min_value > 1e3:
            # span several orders of magnitude like hypothesis does
            lo, hi = math.log(min_value), math.log(max_value)
            return math.exp(rng.uniform(lo, hi))
        return rng.uniform(min_value, max_value)

    return SearchStrategy(draw)


def booleans() -> SearchStrategy:
    return SearchStrategy(lambda rng: rng.random() < 0.5)


def sampled_from(elements: Sequence[Any]) -> SearchStrategy:
    elements = list(elements)
    return SearchStrategy(lambda rng: rng.choice(elements))


def lists(elements: SearchStrategy, min_size: int = 0,
          max_size: int = 10) -> SearchStrategy:
    def draw(rng: random.Random) -> list:
        size = rng.randint(min_size, max_size)
        return [elements.example(rng) for _ in range(size)]

    return SearchStrategy(draw)


def just(value: Any) -> SearchStrategy:
    return SearchStrategy(lambda rng: value)


def composite(fn: Callable) -> Callable:
    """``@st.composite`` — ``fn(draw, *args)`` becomes a strategy factory."""

    def factory(*args, **kwargs) -> SearchStrategy:
        def draw_example(rng: random.Random):
            def draw(strategy: SearchStrategy):
                return strategy.example(rng)

            return fn(draw, *args, **kwargs)

        return SearchStrategy(draw_example)

    return factory
