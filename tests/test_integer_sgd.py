"""Parity + contract suite for ``repro.kernels.integer_sgd``.

The package predated the shared parity harness and the coverage floor;
this file folds it into both, and pins the two dormant-path behaviours
ISSUE 10 fixed:

  * **kernel ≡ ref ≡ optimizer.apply_update**, bitwise, via the
    ``_gradcheck`` backend fixtures — including ragged-tail shapes that
    exercise the (rows, 128) lane padding and ``η_inv = 0`` (decay off);
  * the ``apply_tree_fused`` dispatcher contract: ``backend=`` vocabulary,
    the contradictory ``use_kernel=False``/``interpret=True`` legacy-knob
    ValueError (previously silently resolved in favour of ``use_kernel``),
    an explicit ``interpret=True`` actually selecting the interpreter, and
    ``numerics.assert_int`` validation on every leaf (previously only the
    jnp path validated);
  * the floor-division decay **asymmetry** (hypothesis property): for
    ``0 ≤ w < η_inv`` decay is 0, but every ``−η_inv ≤ w < 0`` decays by
    −1 — i.e. ``w ← w + 1`` at zero gradient — matching Algorithm 1's
    floor semantics exactly (the docstring used to claim the small-|w|
    decay was zero on both sides).
"""

from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from _gradcheck import assert_bitwise_equal, backend_pair, kernel_backend  # noqa: F401
from repro.core import optimizer as opt
from repro.core.numerics import floor_div
from repro.kernels.integer_sgd.integer_sgd import (
    integer_sgd_tile,
    integer_sgd_update,
)
from repro.kernels.integer_sgd.ops import apply_tree_fused
from repro.kernels.integer_sgd.ref import integer_sgd_ref

# Ragged tails on purpose: (7,) under one lane, (129,) one over, (130, 3)
# both rows and lanes ragged, (8, 128) the exact native tile.
SHAPES = [(7,), (3, 5), (129,), (8, 128), (130, 3)]
ETAS = [0, 3000]


def _case(shape, seed=0):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.integers(-9000, 9000, shape), jnp.int32)
    g = jnp.asarray(rng.integers(-(2 ** 17), 2 ** 17, shape), jnp.int32)
    return w, g


def _tree_apply(w, g, gamma_inv, eta_inv, backend):
    state = opt.init_state(gamma_inv, eta_inv)
    return apply_tree_fused({"w": w}, {"w": g}, state, backend=backend)["w"]


class TestKernelParity:
    @pytest.mark.parametrize("eta_inv", ETAS)
    @pytest.mark.parametrize("shape", SHAPES, ids=str)
    def test_kernel_matches_apply_update(self, shape, eta_inv):
        """The Pallas kernel (interpreted off-TPU) ≡ the jnp Algorithm 1,
        bitwise, across ragged padding shapes and decay on/off."""
        w, g = _case(shape, seed=len(shape))
        state = opt.init_state(512, eta_inv)
        got = integer_sgd_update(
            w, g, state.gamma_inv, state.eta_inv, interpret=True
        )
        assert_bitwise_equal(got, opt.apply_update(w, g, state),
                             err_msg=f"{shape} eta={eta_inv}")

    @pytest.mark.parametrize("eta_inv", ETAS)
    def test_ref_matches_apply_update(self, eta_inv):
        w, g = _case((37, 11), seed=3)
        state = opt.init_state(512, eta_inv)
        assert_bitwise_equal(
            integer_sgd_ref(w, g, state.gamma_inv, state.eta_inv),
            opt.apply_update(w, g, state),
        )

    def test_tile_is_the_shared_epilogue_expression(self):
        """``integer_sgd_tile`` (the grad-kernel flush epilogue body) is
        the same function the standalone kernel and the jnp path compute."""
        w, g = _case((64, 128), seed=5)
        state = opt.init_state(1536, 12000)
        assert_bitwise_equal(
            integer_sgd_tile(w, g, state.gamma_inv, state.eta_inv),
            opt.apply_update(w, g, state),
        )

    @pytest.mark.parametrize("eta_inv", ETAS)
    @pytest.mark.parametrize("shape", SHAPES, ids=str)
    def test_backend_pair_parity(self, backend_pair, shape, eta_inv):
        """Every runnable backend pairing agrees bitwise through the
        ``apply_tree_fused`` dispatcher."""
        w, g = _case(shape, seed=7)
        a = _tree_apply(w, g, 512, eta_inv, backend_pair[0])
        b = _tree_apply(w, g, 512, eta_inv, backend_pair[1])
        assert_bitwise_equal(a, b, err_msg=f"{backend_pair} {shape}")

    def test_tree_structure_preserved(self, kernel_backend):
        state = opt.init_state(512, 3000)
        params = {"a": _case((5,), 1)[0], "b": {"c": _case((4, 6), 2)[0]}}
        grads = {"a": _case((5,), 1)[1], "b": {"c": _case((4, 6), 2)[1]}}
        got = apply_tree_fused(params, grads, state, backend=kernel_backend)
        want = opt.apply_tree(params, grads, state)
        assert_bitwise_equal(got, want)


class TestDispatcherContract:
    def _args(self):
        w, g = _case((6, 9), seed=11)
        return {"w": w}, {"w": g}, opt.init_state(512, 3000)

    def test_contradictory_legacy_knobs_raise(self):
        """use_kernel=False + interpret=True used to silently drop the
        interpreter request; it is now the same ValueError class PR 5
        introduced for ``nitro_matmul.ops._legacy_backend``."""
        p, g, s = self._args()
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            with pytest.raises(ValueError, match="contradictory"):
                apply_tree_fused(p, g, s, use_kernel=False, interpret=True)

    def test_backend_and_legacy_knobs_are_exclusive(self):
        p, g, s = self._args()
        with pytest.raises(ValueError, match="not both"):
            apply_tree_fused(p, g, s, backend="reference", use_kernel=True)
        with pytest.raises(ValueError, match="not both"):
            apply_tree_fused(p, g, s, backend="auto", interpret=False)

    def test_unknown_backend_rejected(self):
        p, g, s = self._args()
        with pytest.raises(ValueError, match="backend"):
            apply_tree_fused(p, g, s, backend="cuda")

    def test_legacy_knobs_warn_deprecation(self):
        p, g, s = self._args()
        with pytest.warns(DeprecationWarning, match="use_kernel"):
            apply_tree_fused(p, g, s, use_kernel=False)

    def test_explicit_interpret_selects_the_kernel(self):
        """interpret=True with use_kernel unset must run the Pallas
        interpreter (a ``pallas_call`` in the jaxpr), not fall through to
        the jnp reference because the host has no TPU."""
        p, g, s = self._args()

        def step(pp, gg):
            with warnings.catch_warnings():
                warnings.simplefilter("ignore", DeprecationWarning)
                return apply_tree_fused(pp, gg, s, interpret=True)

        jaxpr = jax.make_jaxpr(step)(p, g)
        prims = {e.primitive.name for e in jaxpr.eqns}

        def all_prims(jx):
            out = set()
            for e in jx.eqns:
                out.add(e.primitive.name)
                for param in e.params.values():
                    items = param if isinstance(param, (tuple, list)) else [param]
                    for it in items:
                        if isinstance(it, jax.core.ClosedJaxpr):
                            out |= all_prims(it.jaxpr)
            return out

        assert "pallas_call" in all_prims(jaxpr.jaxpr), prims
        # and it still agrees with the reference, bitwise
        assert_bitwise_equal(step(p, g), opt.apply_tree(p, g, s))

    def test_float_leaves_rejected_on_every_path(self):
        """The kernel wrapper now validates like ``opt.apply_update``."""
        p, g, s = self._args()
        bad_p = {"w": p["w"].astype(jnp.float32)}
        bad_g = {"w": g["w"].astype(jnp.float32)}
        for backend in ("reference", "interpret"):
            with pytest.raises(TypeError, match="weight"):
                apply_tree_fused(bad_p, g, s, backend=backend)
            with pytest.raises(TypeError, match="gradient"):
                apply_tree_fused(p, bad_g, s, backend=backend)


class TestDecayAsymmetry:
    """Pin the floor-division decay semantics (satellite 2).

    Algorithm 1's decay term is ⌊w/η_inv⌋ with floor (round toward −∞)
    semantics.  The old docstring claimed it "zeroes" for |w| < η_inv;
    in fact that holds only for 0 ≤ w < η_inv — every small *negative*
    weight decays by −1, i.e. gains +1 per zero-gradient step.
    """

    @settings(max_examples=200, deadline=None)
    @given(w=st.integers(-2999, -1), eta_inv=st.integers(1, 3000))
    def test_small_negative_weights_step_toward_zero(self, w, eta_inv):
        if w < -eta_inv:
            w = -(abs(w) % eta_inv) or -1  # keep −η_inv < w < 0
        state = opt.init_state(512, eta_inv)
        new_w = opt.apply_update(
            jnp.asarray([w], jnp.int32), jnp.asarray([0], jnp.int32), state
        )
        assert int(new_w[0]) == w + 1, (w, eta_inv)

    @settings(max_examples=200, deadline=None)
    @given(w=st.integers(0, 2999), eta_inv=st.integers(1, 3000))
    def test_small_positive_weights_are_untouched(self, w, eta_inv):
        w = w % eta_inv  # keep 0 ≤ w < η_inv
        state = opt.init_state(512, eta_inv)
        new_w = opt.apply_update(
            jnp.asarray([w], jnp.int32), jnp.asarray([0], jnp.int32), state
        )
        assert int(new_w[0]) == w, (w, eta_inv)

    @settings(max_examples=200, deadline=None)
    @given(w=st.integers(-(2 ** 20), 2 ** 20), eta_inv=st.integers(1, 30000),
           g=st.integers(-(2 ** 20), 2 ** 20))
    def test_update_matches_pure_python_floor(self, w, eta_inv, g):
        """The whole update against Python's // (true floor division)."""
        gamma_inv = 512
        state = opt.init_state(gamma_inv, eta_inv)
        got = opt.apply_update(
            jnp.asarray([w], jnp.int32), jnp.asarray([g], jnp.int32), state
        )
        want = w - (g // gamma_inv + w // eta_inv)
        assert int(got[0]) == want

    def test_negative_weight_trajectory_reaches_zero_and_stays(self):
        """At zero gradient a small negative weight climbs one unit per
        step until it reaches 0, then never moves again."""
        state = opt.init_state(512, 3000)
        w = jnp.asarray([-4], jnp.int32)
        g = jnp.zeros_like(w)
        seen = []
        for _ in range(7):
            w = opt.apply_update(w, g, state)
            seen.append(int(w[0]))
        assert seen == [-3, -2, -1, 0, 0, 0, 0]

    def test_floor_div_is_floor(self):
        """Anchor: ``numerics.floor_div`` rounds toward −∞, not zero."""
        got = floor_div(jnp.asarray([-1, -2999, 1, 2999], jnp.int32),
                        jnp.asarray(3000, jnp.int32))
        np.testing.assert_array_equal(np.asarray(got), [-1, -1, 0, 0])
