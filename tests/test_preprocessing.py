"""Integer MAD pre-processing (Appendix B.2) + one-hot(32) encoding."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import losses, preprocessing


class TestPreprocessing:
    def test_gaussian_lands_at_sigma_64(self):
        rng = np.random.default_rng(0)
        x = rng.normal(120, 35, (50000,)).astype(np.int32)  # uint8-ish images
        xh = np.asarray(preprocessing.preprocess(jnp.asarray(x)))
        assert abs(xh.mean()) < 2.0
        assert abs(xh.std() - 64) < 4.0
        # ≈95 % within [-127, 127]
        frac = np.mean(np.abs(xh) <= 127)
        assert frac > 0.93

    def test_multiplier_is_51(self):
        assert preprocessing.MAD_TARGET_MULTIPLIER == 51  # ⌊64·0.8⌋

    @given(st.lists(st.integers(0, 255), min_size=10, max_size=500))
    @settings(max_examples=50, deadline=None)
    def test_integer_only_and_matches_reference(self, xs):
        x = np.asarray(xs, np.int32)
        mu, omega = preprocessing.integer_statistics(x)
        assert mu == int(x.astype(np.int64).sum() // x.size)
        assert omega == int(np.abs(x.astype(np.int64) - mu).sum() // x.size)
        xh = np.asarray(preprocessing.normalize(jnp.asarray(x), mu, omega))
        want = (x - mu) * 51 // max(omega, 1)
        np.testing.assert_array_equal(xh, want)


class TestOneHot:
    def test_value_is_32(self):
        y = np.asarray(losses.one_hot_int(jnp.asarray([2, 0]), 4))
        np.testing.assert_array_equal(y, [[0, 0, 32, 0], [32, 0, 0, 0]])

    def test_gradient_bitwidth_bound(self):
        """b_∇L = 6: with ŷ within the one-hot range, |∇L| < 2⁶."""
        y_hat = jnp.asarray([[30, 0, 5]], jnp.int32)
        y = losses.one_hot_int(jnp.asarray([0]), 3)
        g = np.asarray(losses.rss_grad(y_hat, y))
        assert np.abs(g).max() < 2**6

    def test_rss_loss_integer(self):
        y_hat = jnp.asarray([[10, 0]], jnp.int32)
        y = jnp.asarray([[32, 0]], jnp.int32)
        assert int(losses.rss_loss(y_hat, y)) == (22 * 22) // 2
