"""repro.obs.health: alert-rule semantics on synthetic telemetry streams.

Every rule is driven with handcrafted record streams so the windowed /
hysteretic behaviours are pinned exactly:

  * edge triggering — an alert fires on the inactive→firing transition
    only, stays silently active while the condition holds, and re-arms
    only at the (stricter) clear threshold;
  * severity escalation re-fires (warning → critical) without clearing;
  * window edges — trend rules stay quiet until their window is full,
    and a single non-monotone sample resets a trend;
  * the monitor's registry wiring (``obs_alerts_total``,
    ``obs_headroom_bits``, ``dp_grad_fits_int16``), sink fan-out, and
    the offline ``scan_jsonl`` replay being equivalent to online
    feeding.
"""

import json

import pytest

from repro.obs import health as H
from repro.obs.metrics import MetricRegistry


def tensor(msb=10, sat8_frac=0.0, sat32_frac=0.0, max_abs=None):
    return {
        "msb": msb,
        "max_abs": (1 << msb) - 1 if max_abs is None else max_abs,
        "sat_int8_frac": sat8_frac,
        "sat_int32_frac": sat32_frac,
    }


def block_row(step, layer="block0", *, grad=None, act=None, dead_frac=0.0):
    return {
        "step": step, "layer": layer, "kind": "conv",
        "grad": grad or tensor(),
        "act": act or tensor(msb=7),
        "dead_frac": dead_frac,
    }


def opt_row(step, **scalars):
    return {"step": step, "layer": "_opt",
            **({"eta_inv_lr": 512} | scalars)}


# ---------------------------------------------------------------------------
# primitives
# ---------------------------------------------------------------------------


class TestPrimitives:
    def test_alert_json_and_format(self):
        a = H.Alert(rule="r", severity="critical", step=3, layer="block1",
                    signal="grad.msb", value=2.0, threshold=4.0,
                    message="boom")
        assert a.to_json()["severity"] == "critical"
        assert "[CRITICAL] step 3 block1 r: boom" == a.format()
        run_wide = H.Alert(rule="r", severity="info", step=0, layer="",
                           signal="s", value=0, threshold=0, message="m")
        assert "[INFO] step 0 r: m" == run_wide.format()

    def test_unknown_severity_rejected(self):
        with pytest.raises(ValueError, match="unknown severity"):
            H.SaturationTrendRule(severity="fatal")

    def test_window_must_be_positive(self):
        with pytest.raises(ValueError, match="window"):
            H.DeadUnitGrowthRule(window=0)

    def test_monotone_growth(self):
        assert H._is_monotone_growth([1, 1, 2])
        assert not H._is_monotone_growth([1, 1, 1])   # no net growth
        assert not H._is_monotone_growth([1, 3, 2])   # not monotone

    def test_group_steps_contiguous_and_restart(self):
        rows = [{"step": 1, "layer": "a"}, {"step": 1, "layer": "b"},
                {"step": 2, "layer": "a"}, {"step": 1, "layer": "a"}]
        groups = H.group_steps(rows)
        assert [s for s, _ in groups] == [1, 2, 1]
        assert sorted(groups[0][1]) == ["a", "b"]


# ---------------------------------------------------------------------------
# rules
# ---------------------------------------------------------------------------


class TestSaturationTrendRule:
    def make(self, **kw):
        return H.SaturationTrendRule(
            field="sat_int8_frac", tensors=("act",), fire=0.2, clear=0.05,
            trend_fire=0.1, window=3, **kw)

    def test_hard_fire_is_edge_triggered_with_hysteresis(self):
        rule = self.make()
        fire = lambda frac, step: rule.observe(
            step, {"block0": block_row(step, act=tensor(sat8_frac=frac))})
        assert fire(0.1, 0) == []          # healthy
        fired = fire(0.3, 1)               # crosses the hard threshold
        assert [a.severity for a in fired] == ["warning"]
        assert fired[0].signal == "act.sat_int8_frac"
        assert fire(0.5, 2) == []          # still firing: silent
        assert fire(0.1, 3) == []          # below fire but above clear
        assert rule.active                 # ... so still active
        assert fire(0.04, 4) == []         # clears
        assert not rule.active
        assert len(fire(0.3, 5)) == 1      # re-armed: fires again

    def test_trend_fires_only_on_full_monotone_window(self):
        rule = self.make()
        obs = lambda frac, step: rule.observe(
            step, {"block0": block_row(step, act=tensor(sat8_frac=frac))})
        assert obs(0.11, 0) == []          # window not full
        assert obs(0.12, 1) == []
        fired = obs(0.13, 2)               # full + monotone + > trend_fire
        assert len(fired) == 1
        assert "rising monotonically" in fired[0].message

    def test_non_monotone_window_stays_quiet(self):
        rule = self.make()
        for step, frac in enumerate([0.11, 0.14, 0.12]):
            fired = rule.observe(step, {
                "block0": block_row(step, act=tensor(sat8_frac=frac))})
        assert fired == []

    def test_rows_without_the_field_are_skipped(self):
        rule = self.make()
        assert rule.observe(0, {"_opt": opt_row(0)}) == []


class TestHeadroomRule:
    def test_bad_thresholds_rejected(self):
        with pytest.raises(ValueError, match="critical_bits"):
            H.HeadroomRule(warn_bits=2, critical_bits=4, clear_bits=6)

    def test_warning_then_escalation_then_clear(self):
        rule = H.HeadroomRule(warn_bits=4, critical_bits=2, clear_bits=6)
        obs = lambda msb, step: rule.observe(
            step, {"block0": block_row(step, grad=tensor(msb=msb))})
        assert obs(20, 0) == []                      # 11 bits headroom
        fired = obs(28, 1)                           # 3 bits → warning
        assert [a.severity for a in fired] == ["warning"]
        assert fired[0].value == 3.0
        assert obs(28, 2) == []                      # active, silent
        fired = obs(30, 3)                           # 1 bit → escalates
        assert [a.severity for a in fired] == ["critical"]
        assert obs(28, 4) == []                      # 3 bits: not cleared
        assert rule.active
        assert obs(20, 5) == []                      # >= clear_bits: clears
        assert not rule.active


class TestDeadUnitGrowthRule:
    def test_monotone_growth_fires_warning(self):
        rule = H.DeadUnitGrowthRule(window=4, min_growth=0.1, ceiling=0.9)
        for step, d in enumerate([0.1, 0.15, 0.2]):
            assert rule.observe(step, {
                "block0": block_row(step, dead_frac=d)}) == []
        fired = rule.observe(3, {"block0": block_row(3, dead_frac=0.25)})
        assert [a.severity for a in fired] == ["warning"]
        assert "grew" in fired[0].message
        # growth stops under the ceiling → clears, then re-arms
        assert rule.observe(4, {
            "block0": block_row(4, dead_frac=0.2)}) == []
        assert not rule.active

    def test_ceiling_is_critical_even_without_growth(self):
        rule = H.DeadUnitGrowthRule(window=4, min_growth=0.1, ceiling=0.5)
        fired = rule.observe(0, {"block0": block_row(0, dead_frac=0.8)})
        assert [a.severity for a in fired] == ["critical"]
        assert "ceiling" in fired[0].message

    def test_growth_escalates_to_ceiling(self):
        rule = H.DeadUnitGrowthRule(window=3, min_growth=0.1, ceiling=0.6)
        stream = [0.2, 0.3, 0.45, 0.7]
        fired = []
        for step, d in enumerate(stream):
            fired += rule.observe(step, {
                "block0": block_row(step, dead_frac=d)})
        assert [a.severity for a in fired] == ["warning", "critical"]


class TestOptimizerStallRule:
    def test_fires_per_scalar_and_clears(self):
        rule = H.OptimizerStallRule(max_scalar=1 << 10)
        assert rule.observe(0, {"_opt": opt_row(0)}) == []
        fired = rule.observe(1, {"_opt": opt_row(1, eta_inv_lr=1 << 12,
                                                 gamma_inv_fw=1 << 11)})
        assert sorted(a.signal for a in fired) == [
            "opt.eta_inv_lr", "opt.gamma_inv_fw"]
        assert rule.observe(2, {"_opt": opt_row(
            2, eta_inv_lr=1 << 12, gamma_inv_fw=1 << 11)}) == []
        # restored-from-checkpoint run: scalar back down → clears
        assert rule.observe(3, {"_opt": opt_row(3)}) == []
        assert len(rule.active) == 1  # gamma_inv_fw absent → state kept

    def test_no_opt_row_is_a_noop(self):
        rule = H.OptimizerStallRule()
        assert rule.observe(0, {"block0": block_row(0)}) == []


class TestDpCompressFitRule:
    def test_fires_on_zero_and_clears_on_one(self):
        rule = H.DpCompressFitRule()
        dp = lambda fits, step: rule.observe(
            step, {"_dp": {"step": step, "layer": "_dp",
                           "grad_fits_int16": fits, "shards": 4}})
        assert dp(1, 0) == []
        fired = dp(0, 1)
        assert [a.rule for a in fired] == ["dp_compress_fit"]
        assert dp(0, 2) == []
        assert dp(1, 3) == []
        assert not rule.active

    def test_single_device_runs_have_no_dp_row(self):
        rule = H.DpCompressFitRule()
        assert rule.observe(0, {"block0": block_row(0)}) == []


# ---------------------------------------------------------------------------
# monitor
# ---------------------------------------------------------------------------


class TestHealthMonitor:
    def test_default_rules_cover_the_catalogue(self):
        names = {r.name for r in H.default_rules()}
        assert names == {"saturation[int32]", "saturation[int8]",
                         "headroom", "dead_units", "opt_scalar_stall",
                         "dp_compress_fit"}

    def test_counters_gauges_and_sinks(self):
        reg = MetricRegistry()
        seen = []
        mon = H.HealthMonitor(registry=reg, sinks=[seen.append])
        mon.observe_records([
            block_row(0, grad=tensor(msb=30)),       # headroom critical
            opt_row(0, eta_inv_lr=1 << 21),          # stall warning
            {"step": 0, "layer": "_dp", "grad_fits_int16": 1, "shards": 2},
        ])
        assert len(seen) == 2
        assert mon.steps_observed == 1
        crit = reg.counter("obs_alerts_total", labels=("rule", "severity"))
        assert crit.labels(rule="headroom", severity="critical").value == 1
        hdrm = reg.gauge("obs_headroom_bits", labels=("layer", "tensor"))
        assert hdrm.labels(layer="block0", tensor="grad").value == 1
        assert hdrm.labels(layer="block0", tensor="act").value == 24
        assert reg.gauge("dp_grad_fits_int16").value == 1
        active = reg.gauge("obs_alerts_active", labels=("rule",))
        assert active.labels(rule="headroom").value == 1
        assert active.labels(rule="dead_units").value == 0

    def test_active_alerts_sorted_most_severe_first(self):
        mon = H.HealthMonitor()
        mon.observe_records([
            block_row(0, grad=tensor(msb=28)),       # headroom warning
            opt_row(0, eta_inv_lr=1 << 21),          # stall warning
            block_row(0, layer="block1",
                      grad=tensor(msb=10, sat32_frac=0.01)),  # critical
        ])
        sevs = [a.severity for a in mon.active_alerts()]
        assert sevs == sorted(sevs, key=H.SEVERITIES.index, reverse=True)
        summary = mon.summary()
        assert summary["alerts_fired"] == 3
        assert summary["by_severity"]["critical"] == 1
        assert len(summary["active"]) == 3

    def test_registry_is_optional(self):
        mon = H.HealthMonitor(rules=[H.HeadroomRule()])
        fired = mon.observe_records([block_row(0, grad=tensor(msb=30))])
        assert len(fired) == 1

    def test_scan_jsonl_equals_online_feed(self, tmp_path, capsys):
        rows = []
        for step, (msb, dead) in enumerate(
                [(10, 0.0), (29, 0.1), (29, 0.2), (10, 0.1)]):
            rows.append(block_row(2 * step, grad=tensor(msb=msb),
                                  dead_frac=dead))
            rows.append(opt_row(2 * step))
        path = tmp_path / "metrics.jsonl"
        path.write_text("".join(json.dumps(r) + "\n" for r in rows))

        online = H.HealthMonitor()
        online.observe_records(rows)
        offline = H.scan_jsonl(str(path), sinks=[H.print_sink])
        assert ([a.to_json() for a in offline.alerts]
                == [a.to_json() for a in online.alerts])
        assert offline.summary() == online.summary()
        out = capsys.readouterr().out
        assert out.count("[alert]") == len(offline.alerts) > 0

    def test_jsonl_sink_appends_alert_rows(self, tmp_path):
        path = str(tmp_path / "alerts.jsonl")
        mon = H.HealthMonitor(sinks=[H.jsonl_sink(path)])
        mon.observe_records([block_row(0, grad=tensor(msb=31))])
        mon.observe_records([block_row(1, grad=tensor(msb=10)),
                             block_row(2, grad=tensor(msb=31))])
        with open(path) as f:
            rows = [json.loads(line) for line in f]
        assert [r["step"] for r in rows] == [0, 2]
        assert all(r["rule"] == "headroom" for r in rows)
