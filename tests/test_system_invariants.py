"""Property-based tests (hypothesis) on the system's invariants:

  * integer closure: a randomly-shaped NITRO-D model's train step contains
    no float op and keeps activations within the int8 operational range;
  * the NITRO scaling bit-width guarantee holds for random (fan-in, value)
    draws at the worst case;
  * IntegerSGD updates are bounded by ⌊|g|/γ⌋ + ⌊|w|/η⌋ (no surprise jumps);
  * gradient compression round-trip error is bounded by the quantisation
    grid for arbitrary tensors;
  * checkpoint save/restore is an exact identity for integer trees.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import les
from repro.core.blocks import BlockSpec
from repro.core.model import NitroConfig


@st.composite
def nitro_architectures(draw):
    """Random small NITRO-D architectures (conv/linear mixes)."""
    n_conv = draw(st.integers(0, 2))
    n_lin = draw(st.integers(1, 2))
    blocks = []
    for i in range(n_conv):
        blocks.append(BlockSpec(
            "conv", draw(st.sampled_from([4, 8])),
            pool=draw(st.booleans()), d_lr=64,
        ))
    for _ in range(n_lin):
        blocks.append(BlockSpec("linear", draw(st.sampled_from([16, 32]))))
    cfg = NitroConfig(
        blocks=tuple(blocks),
        input_shape=(8, 8, 2) if n_conv else (32,),
        num_classes=draw(st.sampled_from([4, 10])),
        gamma_inv=draw(st.sampled_from([256, 512, 1024])),
        eta_fw=draw(st.sampled_from([0, 20000])),
        eta_lr=draw(st.sampled_from([0, 5000])),
    )
    return cfg


class TestIntegerClosure:
    @given(nitro_architectures(), st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_train_step_integer_only_any_architecture(self, cfg, seed):
        rng = np.random.default_rng(seed)
        x = jnp.asarray(
            rng.integers(-127, 128, (4, *cfg.input_shape)), jnp.int32
        )
        labels = jnp.asarray(rng.integers(0, cfg.num_classes, (4,)), jnp.int32)
        state = les.create_train_state(jax.random.PRNGKey(seed % 2**31), cfg)
        jaxpr = jax.make_jaxpr(functools.partial(les.train_step, cfg=cfg))(
            state, x=x, labels=labels, key=jax.random.PRNGKey(0)
        )
        for eqn in jaxpr.jaxpr.eqns:
            for v in list(eqn.invars) + list(eqn.outvars):
                aval = getattr(v, "aval", None)
                if aval is not None and hasattr(aval, "dtype"):
                    assert "float" not in str(aval.dtype)

    @given(nitro_architectures(), st.integers(0, 2**31 - 1))
    @settings(max_examples=8, deadline=None)
    def test_activations_in_int8_range_any_architecture(self, cfg, seed):
        from repro.core import model as M

        rng = np.random.default_rng(seed)
        x = jnp.asarray(
            rng.integers(-127, 128, (4, *cfg.input_shape)), jnp.int32
        )
        params = M.init_params(jax.random.PRNGKey(seed % 2**31), cfg)
        _, acts, _, _ = M.forward(params, cfg, x, train=False)
        for a in acts:
            assert int(jnp.abs(a).max()) <= 127


class TestUpdateBounds:
    @given(
        st.integers(-(2**15), 2**15), st.integers(-(2**24), 2**24),
        st.integers(1, 2**12), st.integers(0, 2**14),
    )
    @settings(max_examples=200, deadline=None)
    def test_update_magnitude_bounded(self, w, g, gamma, eta):
        from repro.core import optimizer as opt

        state = opt.init_state(gamma, eta)
        new = int(opt.apply_update(jnp.int32(w), jnp.int32(g), state))
        bound = abs(g) // gamma + (abs(w) // eta if eta else 0) + 2
        assert abs(new - w) <= bound


class TestCompressionBounds:
    @given(st.integers(0, 2**31 - 1), st.floats(1e-8, 1e3))
    @settings(max_examples=50, deadline=None)
    def test_roundtrip_error_within_grid(self, seed, scale):
        from repro.parallel import compress

        rng = np.random.default_rng(seed)
        g = {"w": jnp.asarray(rng.normal(0, scale, (128,)), jnp.float32)}
        ef = compress.ef_init(g)
        q, s, ef = compress.compress(g, ef)
        back = compress.decompress(q, s)
        err = np.abs(np.asarray(back["w"]) - np.asarray(g["w"])).max()
        assert err <= float(s["w"]) * 0.5 + 1e-9


class TestCheckpointIdentity:
    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_integer_tree_roundtrip_exact(self, seed):
        import tempfile

        from repro.train import checkpoint as ckpt

        rng = np.random.default_rng(seed)
        tree = {
            "a": jnp.asarray(rng.integers(-(2**30), 2**30, (17,)), jnp.int32),
            "b": [jnp.asarray(rng.integers(0, 255, (3, 5)), jnp.int32)],
        }
        with tempfile.TemporaryDirectory() as path:
            ckpt.save(path, 1, tree)
            restored, _ = ckpt.restore(path, tree)
        for x, y in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
