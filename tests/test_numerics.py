"""Unit + property tests for the integer arithmetic primitives."""

import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import numerics


class TestFloorDiv:
    def test_rounds_toward_neg_infinity(self):
        # The paper's ⌊·⌋ is mathematical floor, not C truncation.
        assert int(numerics.floor_div(jnp.int32(-7), 2)) == -4
        assert int(numerics.floor_div(jnp.int32(7), 2)) == 3
        assert int(numerics.floor_div(jnp.int32(-1), 512)) == -1

    @given(st.integers(-(2**20), 2**20), st.integers(1, 2**16))
    @settings(max_examples=200, deadline=None)
    def test_matches_python_floor(self, x, d):
        assert int(numerics.floor_div(jnp.int32(x), d)) == x // d


class TestIntMatmul:
    @given(
        st.integers(1, 8), st.integers(1, 8), st.integers(1, 8),
        st.integers(0, 2**31 - 1),
    )
    @settings(max_examples=50, deadline=None)
    def test_matches_numpy_int64(self, m, k, n, seed):
        rng = np.random.default_rng(seed)
        a = rng.integers(-127, 128, (m, k)).astype(np.int32)
        w = rng.integers(-127, 128, (k, n)).astype(np.int32)
        got = np.asarray(numerics.int_matmul(jnp.asarray(a), jnp.asarray(w)))
        want = (a.astype(np.int64) @ w.astype(np.int64)).astype(np.int32)
        np.testing.assert_array_equal(got, want)

    def test_accumulates_in_int32(self):
        a = jnp.full((1, 1000), 127, jnp.int32)
        w = jnp.full((1000, 1), 127, jnp.int32)
        out = numerics.int_matmul(a, w)
        assert out.dtype == jnp.int32
        assert int(out[0, 0]) == 127 * 127 * 1000


class TestIsqrt:
    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=300, deadline=None)
    def test_matches_math_isqrt(self, n):
        assert int(numerics.isqrt(jnp.int32(n))) == math.isqrt(n)

    def test_jit_and_vmap(self):
        ns = jnp.arange(0, 100, dtype=jnp.int32)
        got = jax.jit(jax.vmap(numerics.isqrt))(ns)
        want = jnp.asarray([math.isqrt(i) for i in range(100)], jnp.int32)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


class TestBitwidthBound:
    def test_paper_example(self):
        # §3.2: b_a = 8, b_W = 8 → b_z = 15 + log2(M)
        assert numerics.bitwidth_bound(8, 8, 1024) == 15 + 10

    def test_assert_int_rejects_float(self):
        with pytest.raises(TypeError):
            numerics.assert_int(jnp.zeros((2,), jnp.float32))
