"""Fused IntegerSGD epilogue (``fuse_opt``): kernel contract + parity.

The tentpole guarantee of ISSUE 10: applying the IntegerSGD update in the
gradient kernels' *flush* — read the W tile, write W′, never materialise
grad_W in HBM — changes nothing numerically.  Integer floor-division over
an order-exact int32 accumulation is exact, so

    fused-epilogue step  ≡  compute_gradients → apply_gradients

bit for bit, on both paper configs, every runnable backend, both conv
data paths, over multi-step trajectories.  On top of parity, the fused
path is held to its structural claims: no full-size grad_W-shaped
floor-division output exists outside a Pallas kernel body, and the whole
fused-opt step stays float-free.

Parity assertions go through ``tests/_gradcheck.py``.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _gradcheck import (  # noqa: F401  (fixtures)
    AVAILABLE_BACKENDS,
    assert_bitwise_equal,
    assert_jaxpr_integer_only,
    backend_pair,
    eqn_output_shapes,
    kernel_backend,
)
from repro.configs import paper
from repro.core import blocks as B
from repro.core import les, model as M
from repro.core import optimizer as opt
from repro.core.blocks import BlockSpec
from repro.core.model import NitroConfig
from repro.kernels import grad_ops
from repro.kernels.nitro_conv import conv_grad_w, conv_grad_w_opt
from repro.kernels.nitro_matmul import grad_w_matmul, grad_w_opt_matmul


def _linear_case(b, m, n, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-127, 128, (b, m)), jnp.int32)
    delta = jnp.asarray(rng.integers(-63, 64, (b, n)), jnp.int32)
    z_star = jnp.asarray(rng.integers(-300, 301, (b, n)), jnp.int32)
    w = jnp.asarray(rng.integers(-40, 41, (m, n)), jnp.int32)
    return x, delta, z_star, w


def _conv_case(n, h, w_sp, c, f, k, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-127, 128, (n, h, w_sp, c)), jnp.int32)
    delta = jnp.asarray(rng.integers(-63, 64, (n, h, w_sp, f)), jnp.int32)
    z_star = jnp.asarray(rng.integers(-300, 301, (n, h, w_sp, f)), jnp.int32)
    w = jnp.asarray(rng.integers(-40, 41, (k, k, c, f)), jnp.int32)
    return x, delta, z_star, w


OPT = opt.init_state(512, 12000)
OPT_NO_DECAY = opt.init_state(512, 0)


# ---------------------------------------------------------------------------
# Kernel/dispatcher level: the flush epilogue ≡ grad-then-update
# ---------------------------------------------------------------------------


class TestLinearOptEpilogue:
    @pytest.mark.parametrize("state", [OPT, OPT_NO_DECAY],
                             ids=["decay", "no-decay"])
    def test_matches_grad_then_update(self, kernel_backend, state):
        x, delta, z_star, w = _linear_case(12, 40, 24, seed=1)
        got = grad_w_opt_matmul(
            x, delta, z_star, w, state.gamma_inv, state.eta_inv,
            backend=kernel_backend,
        )
        grad_w = grad_w_matmul(x, delta, z_star, backend=kernel_backend)
        assert_bitwise_equal(got, opt.apply_update(w, grad_w, state),
                             err_msg=kernel_backend)

    def test_backend_pair_parity(self, backend_pair):
        # ragged dims on purpose: the epilogue must be exact through the
        # tile padding (padded acc = 0, padded w = 0 → W' = 0, sliced off)
        x, delta, z_star, w = _linear_case(9, 130, 70, seed=2)
        a, b = (
            grad_w_opt_matmul(
                x, delta, z_star, w, OPT.gamma_inv, OPT.eta_inv, backend=bk
            )
            for bk in backend_pair
        )
        assert_bitwise_equal(a, b, err_msg=str(backend_pair))

    def test_dispatcher_escape_hatches(self, kernel_backend):
        """z_star=None and fuse_bwd=False route through the materialised
        gradient + ``opt.apply_update`` — same result, bitwise."""
        x, delta, z_star, w = _linear_case(8, 32, 16, seed=3)
        want_gx, want_w = grad_ops.linear_weight_update(
            x, w, delta, OPT, z_star=z_star, backend=kernel_backend
        )
        for kw in (dict(z_star=z_star, fuse_bwd=False), dict(z_star=None)):
            got_gx, got_w = grad_ops.linear_weight_update(
                x, w, delta, OPT, backend=kernel_backend, **kw
            )
            if kw.get("z_star") is not None:
                assert_bitwise_equal(got_w, want_w, err_msg=str(kw))
                assert_bitwise_equal(got_gx, want_gx, err_msg=str(kw))
            else:
                # no z*: STE-only backward — different math by design;
                # still must equal its own grad-then-update composition
                _, gw = grad_ops.linear_grads(x, w, delta)
                assert_bitwise_equal(got_w, opt.apply_update(w, gw, OPT))


class TestConvOptEpilogue:
    @pytest.mark.parametrize("state", [OPT, OPT_NO_DECAY],
                             ids=["decay", "no-decay"])
    def test_matches_grad_then_update(self, kernel_backend, state):
        x, delta, z_star, w = _conv_case(2, 8, 6, 3, 8, 3, seed=4)
        got = conv_grad_w_opt(
            x, delta, w, state.gamma_inv, state.eta_inv,
            kernel_size=3, z_star=z_star, backend=kernel_backend,
        )
        grad_w = conv_grad_w(
            x, delta, kernel_size=3, z_star=z_star, backend=kernel_backend
        )
        assert_bitwise_equal(got, opt.apply_update(w, grad_w, state),
                             err_msg=kernel_backend)

    def test_backend_pair_parity(self, backend_pair):
        x, delta, z_star, w = _conv_case(2, 9, 7, 3, 5, 3, seed=5)
        a, b = (
            conv_grad_w_opt(
                x, delta, w, OPT.gamma_inv, OPT.eta_inv,
                kernel_size=3, z_star=z_star, backend=bk
            )
            for bk in backend_pair
        )
        assert_bitwise_equal(a, b, err_msg=str(backend_pair))

    def test_materialise_mode_rejected(self):
        """No kernel flush to fuse into — the dispatcher refuses rather
        than silently downgrading."""
        x, delta, z_star, w = _conv_case(1, 4, 4, 2, 4, 3, seed=6)
        with pytest.raises(ValueError, match="stream-only"):
            conv_grad_w_opt(
                x, delta, w, OPT.gamma_inv, OPT.eta_inv,
                kernel_size=3, z_star=z_star, conv_mode="materialise",
            )

    @pytest.mark.parametrize("kw", [
        dict(fuse_bwd=False), dict(conv_mode="materialise")
    ], ids=["unfused-bwd", "materialise"])
    def test_weight_update_escape_hatches(self, kernel_backend, kw):
        """``conv_weight_update`` takes the grad-then-update hatch for
        unfused-bwd and materialise mode — bitwise equal to the fused
        stream path."""
        x, delta, z_star, w = _conv_case(2, 8, 6, 3, 8, 3, seed=7)
        want_gx, want_w = grad_ops.conv_weight_update(
            x, w, delta, OPT, z_star=z_star, backend=kernel_backend
        )
        got_gx, got_w = grad_ops.conv_weight_update(
            x, w, delta, OPT, z_star=z_star, backend=kernel_backend, **kw
        )
        assert_bitwise_equal(got_w, want_w, err_msg=str(kw))
        assert_bitwise_equal(got_gx, want_gx, err_msg=str(kw))


# ---------------------------------------------------------------------------
# Train-step level: fuse_opt ≡ the split composition, multi-step
# ---------------------------------------------------------------------------


def _step_args(cfg, batch, seed=4):
    st = les.create_train_state(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-127, 128, (batch, *cfg.input_shape)),
                    jnp.int32)
    y = jnp.asarray(rng.integers(0, cfg.num_classes, batch), jnp.int32)
    return st, x, y


class TestTrainStepFuseOptParity:
    @pytest.mark.parametrize("conv_mode", ["stream", "materialise"])
    @pytest.mark.parametrize("arch,batch", [("vgg8b", 4), ("vgg11b", 2)])
    def test_multi_step_trajectory_bit_exact(self, arch, batch, conv_mode,
                                             kernel_backend):
        """Acceptance criterion: multi-step fuse_opt trajectory ≡ the
        split composition on both paper configs, every runnable backend,
        both conv data paths.  Divergence compounds, so trajectory
        equality is strictly stronger than single-step equality."""
        cfg = paper.get(arch, scale=0.0625)
        st_f, x, y = _step_args(cfg, batch)
        st_u = st_f
        step_f = jax.jit(functools.partial(
            les.train_step, cfg=cfg, fuse_opt=True,
            backend=kernel_backend, conv_mode=conv_mode))
        step_u = jax.jit(functools.partial(
            les.train_step, cfg=cfg, fuse_opt=False,
            backend=kernel_backend, conv_mode=conv_mode))
        for i in range(3):
            k = jax.random.PRNGKey(i)
            st_f, m_f = step_f(st_f, x=x, labels=y, key=k)
            st_u, m_u = step_u(st_u, x=x, labels=y, key=k)
        assert_bitwise_equal(st_f, st_u,
                             err_msg=f"{arch}/{conv_mode}/{kernel_backend}")
        assert_bitwise_equal(m_f, m_u)

    def test_unfused_forward_also_exact(self):
        """fuse_opt composes with the unfused forward escape hatch too
        (z* is cached either way)."""
        cfg = paper.get("vgg8b", scale=0.0625)
        st, x, y = _step_args(cfg, 4)
        key = jax.random.PRNGKey(3)
        got = jax.jit(functools.partial(
            les.train_step, cfg=cfg, fused=False, fuse_opt=True))(
            st, x=x, labels=y, key=key)
        want = jax.jit(functools.partial(
            les.train_step, cfg=cfg, fused=False))(st, x=x, labels=y, key=key)
        assert_bitwise_equal(got[0], want[0])

    def test_telemetry_falls_back_to_split_path(self):
        """telemetry needs the materialised fw gradients, so
        ``fuse_opt=True, telemetry=True`` runs the split path — same
        trajectory, same telemetry as the plain telemetry step."""
        cfg = paper.get("vgg8b", scale=0.0625)
        st, x, y = _step_args(cfg, 4)
        key = jax.random.PRNGKey(5)
        st_a, m_a, telem_a = jax.jit(functools.partial(
            les.train_step, cfg=cfg, fuse_opt=True, telemetry=True))(
            st, x=x, labels=y, key=key)
        st_b, m_b, telem_b = jax.jit(functools.partial(
            les.train_step, cfg=cfg, telemetry=True))(
            st, x=x, labels=y, key=key)
        st_c, _ = jax.jit(functools.partial(
            les.train_step, cfg=cfg, fuse_opt=True))(st, x=x, labels=y, key=key)
        assert_bitwise_equal(st_a, st_b)
        assert_bitwise_equal(telem_a, telem_b)
        assert_bitwise_equal(st_a, st_c)  # fused fast path agrees too

    def test_apply_gradients_fused_kernel_path(self, kernel_backend):
        """``apply_gradients(fuse_opt=True)`` — the DP post-reduce apply —
        is bitwise ``apply_gradients`` through the standalone kernel."""
        cfg = paper.get("vgg8b", scale=0.0625)
        st, x, y = _step_args(cfg, 4)
        grads, _, _ = les.compute_gradients(st, cfg, x, y,
                                            jax.random.PRNGKey(2))
        got = les.apply_gradients(st, grads, fuse_opt=True,
                                  backend=kernel_backend)
        want = les.apply_gradients(st, grads)
        assert_bitwise_equal(got, want, err_msg=kernel_backend)


# ---------------------------------------------------------------------------
# Structural: grad_W never materialises, and the step stays float-free
# ---------------------------------------------------------------------------


# floor_divide lowers to div/rem/select_n; any IntegerSGD update running
# *outside* a Pallas kernel body betrays itself with one of these at the
# updated tensor's full shape.
_UPDATE_PRIMS = ("div", "rem", "select_n")


def _structural_cfg():
    """Widths chosen so the fw-weight shapes collide with nothing else:
    the conv fw weight is the only 4-D tensor, and (256, 48) matches no
    lr/output weight (those end in num_classes=10)."""
    return NitroConfig(
        blocks=(BlockSpec("conv", 16, pool=True, d_lr=256),
                BlockSpec("linear", 48)),
        input_shape=(8, 8, 3), num_classes=10, gamma_inv=512,
        eta_fw=12000, eta_lr=3000,
    )


def _fw_weight_shapes(st):
    return {tuple(p["fw"]["w"].shape) for p in st.params["blocks"]}


class TestFuseOptStructure:
    @pytest.mark.parametrize("backend", ["auto", "interpret"])
    def test_fused_opt_step_is_integer_only(self, backend):
        """Acceptance criterion: the fused-epilogue step is float-free
        end-to-end, descending into every Pallas kernel body."""
        cfg = _structural_cfg()
        st, x, y = _step_args(cfg, 6)
        jaxpr = jax.make_jaxpr(functools.partial(
            les.train_step, cfg=cfg, fuse_opt=True, backend=backend
        ))(st, x=x, labels=y, key=jax.random.PRNGKey(1))
        assert_jaxpr_integer_only(jaxpr.jaxpr)

    def test_no_full_size_grad_w_update_outside_kernels(self):
        """Acceptance criterion: in the fused-opt step no floor-division
        output of a forward-layer weight shape exists outside a Pallas
        kernel body — the update happens in the flush, on VMEM tiles.
        (W′ shares grad_W's shape, so scanning for the *division*
        primitives, not raw avals, is what discriminates: the kernel
        output W′ is legitimate; a div/rem/select at that shape is not.)
        The split step (sanity) shows exactly those shapes."""
        cfg = _structural_cfg()
        st, x, y = _step_args(cfg, 6)
        fw_shapes = _fw_weight_shapes(st)

        def update_shapes(fuse_opt):
            jaxpr = jax.make_jaxpr(functools.partial(
                les.train_step, cfg=cfg, fuse_opt=fuse_opt,
                backend="interpret",
            ))(st, x=x, labels=y, key=jax.random.PRNGKey(1))
            return set(eqn_output_shapes(
                jaxpr.jaxpr, _UPDATE_PRIMS, skip_pallas=True))

        assert not (update_shapes(True) & fw_shapes), (
            "fused-opt step ran an IntegerSGD floor-division on a "
            "full-size fw weight outside the kernels"
        )
        assert update_shapes(False) & fw_shapes, (
            "sanity: the split step should update fw weights in jnp"
        )

    def test_lr_and_output_updates_stay_jnp(self):
        """The learning/output layers keep the jnp update on the fused
        path (their backward has no flush): their weight shapes *do*
        appear — proof the scan above is looking at the right thing."""
        cfg = _structural_cfg()
        st, x, y = _step_args(cfg, 6)
        lr_shapes = {tuple(p["lr"]["w"].shape) for p in st.params["blocks"]}
        lr_shapes.add(tuple(st.params["output"]["w"].shape))
        jaxpr = jax.make_jaxpr(functools.partial(
            les.train_step, cfg=cfg, fuse_opt=True, backend="interpret",
        ))(st, x=x, labels=y, key=jax.random.PRNGKey(1))
        shapes = set(eqn_output_shapes(
            jaxpr.jaxpr, _UPDATE_PRIMS, skip_pallas=True))
        assert shapes & lr_shapes
