"""Distribution substrate: sharding rules, ring collectives, gradient
compression, fault tolerance, checkpointing, data loader.

Multi-rank collective *semantics* are tested in-process with
``jax.vmap(..., axis_name=...)`` — vmap binds a named axis exactly like
shard_map does, so ring schedules built from ``ppermute`` run at any
simulated rank count without any devices (and under coverage).  Real
multi-*device* execution — shard_map over forced host devices — is
covered end-to-end by ``tests/test_data_parallel.py``."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.launch.mesh import make_mesh
from repro.parallel import collectives as coll
from repro.parallel import compress
from repro.parallel import pipeline as pipe
from repro.parallel.sharding import (
    resolve,
    serve_rules,
    shard,
    train_rules,
    use_rules,
)
from repro.train import checkpoint as ckpt
from repro.train import fault_tolerance as ft


class TestShardingRules:
    def test_no_context_is_noop(self):
        x = jnp.ones((4, 4))
        y = shard(x, "batch", "embed")
        assert y is x

    def test_rule_tables_cover_model_axes(self):
        r = train_rules(multi_pod=True)
        assert r["batch"] == ("pod", "data")
        assert r["heads"] == "model"
        assert r["p_fsdp"] == "data"
        s = serve_rules()
        assert s["p_fsdp"] is None  # weights replicated over data at serve

    def test_resolve_inside_context(self):
        mesh = make_mesh((1, 1), ("data", "model"))
        with use_rules(mesh, train_rules()):
            spec = resolve(("batch", None, "heads"))
            assert spec == jax.sharding.PartitionSpec(("data",), None, "model")

    def test_constraint_applies_in_jit(self):
        mesh = make_mesh((1, 1), ("data", "model"))

        def f(x):
            with use_rules(mesh, train_rules()):
                return shard(x * 2, "batch", "embed")

        y = jax.jit(f)(jnp.ones((4, 8)))
        np.testing.assert_array_equal(np.asarray(y), 2 * np.ones((4, 8)))


def _ranks(fn, stacked):
    """Run ``fn`` per-rank over ``stacked``'s leading dim with a bound
    named axis ``"r"`` — vmap's axis_name gives ppermute/psum/axis_index
    the same semantics shard_map would, minus the devices."""
    return jax.vmap(fn, axis_name="r")(stacked)


class TestRingCollectives:
    def _shmap(self, fn, n, *args):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        mesh = make_mesh((n,), ("x",))
        return shard_map(
            fn, mesh=mesh, in_specs=P("x"), out_specs=P("x")
        )(*args)

    def test_ring_all_reduce_single_device(self):
        x = jnp.arange(8.0)
        out = self._shmap(lambda v: coll.ring_all_reduce(v, "x"), 1, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x))

    @pytest.mark.parametrize("n", [2, 3, 4])
    @pytest.mark.parametrize("rows", [8, 7])  # divisible and padded paths
    def test_ring_all_reduce_matches_psum(self, n, rows):
        """The planted-bug regression: every rank must end with the chunks
        in *global* order (a slot schedule finishing on slot r+1 passes a
        sum-only check but permutes the reassembled tensor)."""
        rng = np.random.default_rng(n * 100 + rows)
        x = jnp.asarray(rng.integers(-(2**20), 2**20, (n, rows, 3)), jnp.int32)
        ring = _ranks(lambda v: coll.ring_all_reduce(v, "r"), x)
        ref = _ranks(lambda v: jax.lax.psum(v, "r"), x)
        np.testing.assert_array_equal(np.asarray(ring), np.asarray(ref))
        assert ring.dtype == ref.dtype

    @pytest.mark.parametrize("n", [2, 3, 4])
    def test_ring_reduce_scatter_rank_owns_its_chunk(self, n):
        """Rank r ends holding reduced chunk r — the by-rank contract the
        all-gather reassembly depends on."""
        rng = np.random.default_rng(n)
        x = jnp.asarray(rng.integers(-(2**20), 2**20, (n, 2 * n, 5)), jnp.int32)
        out = _ranks(lambda v: coll.ring_reduce_scatter(v, "r"), x)
        total = np.asarray(x).sum(axis=0, dtype=np.int32)      # (2n, 5)
        chunks = np.split(total, n, axis=0)                    # chunk r
        for r in range(n):
            np.testing.assert_array_equal(np.asarray(out[r]), chunks[r])

    @pytest.mark.parametrize("n", [2, 3, 4])
    def test_ring_all_gather_rank_order(self, n):
        x = jnp.asarray(
            np.arange(n * 6, dtype=np.int32).reshape(n, 2, 3)
        )
        out = _ranks(lambda v: coll.ring_all_gather(v, "r"), x)
        full = np.asarray(x).reshape(n * 2, 3)  # rank r rows at [2r, 2r+2)
        for r in range(n):
            np.testing.assert_array_equal(np.asarray(out[r]), full)

    def test_single_rank_degenerate_paths(self):
        x = jnp.arange(6, dtype=jnp.int32).reshape(3, 2)
        for fn in (coll.ring_all_reduce, coll.ring_reduce_scatter,
                   coll.ring_all_gather):
            out = _ranks(lambda v, f=fn: f(v, "r"), x[None])
            np.testing.assert_array_equal(np.asarray(out[0]), np.asarray(x))

    def test_reduce_scatter_rejects_non_divisible(self):
        x = jnp.zeros((2, 7, 3), jnp.int32)  # 7 rows over 2 ranks
        with pytest.raises(ValueError, match="not divisible"):
            _ranks(lambda v: coll.ring_reduce_scatter(v, "r"), x)


class TestGradientCompression:
    def test_roundtrip_error_bounded(self):
        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.normal(0, 1e-3, (256,)), jnp.float32)}
        ef = compress.ef_init(g)
        q, s, ef = compress.compress(g, ef)
        assert q["w"].dtype == jnp.int8
        back = compress.decompress(q, s)
        err = np.abs(np.asarray(back["w"] - g["w"])).max()
        assert err <= float(s["w"]) / 2 + 1e-12  # half-ulp of the int8 grid

    def test_error_feedback_accumulates(self):
        """EF: the quantisation residual re-enters the next step — the
        *running sum* of compressed gradients tracks the true sum."""
        rng = np.random.default_rng(1)
        true_sum = np.zeros(64, np.float32)
        comp_sum = np.zeros(64, np.float32)
        g0 = {"w": jnp.zeros((64,), jnp.float32)}
        ef = compress.ef_init(g0)
        for i in range(30):
            g = rng.normal(0, 1e-4, 64).astype(np.float32)
            true_sum += g
            q, s, ef = compress.compress({"w": jnp.asarray(g)}, ef)
            comp_sum += np.asarray(compress.decompress(q, s)["w"])
        resid = np.abs(np.asarray(ef.residual["w"])).max()
        # EF invariant: |Σtrue − Σcompressed| == |residual| (bounded, no drift)
        drift = np.abs(true_sum - comp_sum).max()
        assert drift <= resid + 1e-6

    def test_integer_gradients_sum_exactly(self):
        """NITRO path: int32 gradient reduction is exact (no compression)."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        mesh = make_mesh((1,), ("pod",))
        g = {"w": jnp.asarray([2**30, -(2**30), 123], jnp.int32)}
        out = shard_map(
            lambda t: compress.exact_integer_psum(t, "pod"),
            mesh=mesh, in_specs=P(), out_specs=P(),
        )(g)
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(g["w"]))

    @pytest.mark.parametrize("n", [2, 3])
    def test_ef_compressed_psum_tracks_true_sum(self, n):
        """FP path across ranks: int8 payloads sum against the pmax'd
        scale; the result tracks the true cross-rank sum within one
        global-scale ulp per rank (approximate — unlike the NITRO path)."""
        rng = np.random.default_rng(n)
        shards = rng.normal(0, 1e-3, (n, 32)).astype(np.float32)
        g = {"w": jnp.asarray(shards)}
        ef = compress.ef_init({"w": g["w"][0]})

        def body(t):
            out, _ = compress.compressed_psum(t, ef, "r")
            return out

        out = _ranks(lambda v: body({"w": v}), g["w"])
        true = shards.sum(axis=0)
        # every rank agrees (payloads+scale are identical after pmax) ...
        for r in range(1, n):
            np.testing.assert_array_equal(
                np.asarray(out["w"][r]), np.asarray(out["w"][0]))
        # ... and tracks the true sum to n quantisation ulps
        _, s, _ = compress.compress(
            {"w": jnp.asarray(np.abs(shards).max(axis=0))},
            compress.ef_init({"w": g["w"][0]}))
        tol = n * float(s["w"])
        assert np.abs(np.asarray(out["w"][0]) - true).max() <= tol

    @pytest.mark.parametrize("num_limbs", [2, 3, 4])
    def test_limb_pack_roundtrip(self, num_limbs):
        """pack → (1-shard) unpack is the identity on in-range values."""
        bound = 2 ** (8 * num_limbs - 1)
        rng = np.random.default_rng(num_limbs)
        g = jnp.asarray(
            np.concatenate([
                rng.integers(-bound, bound, 61),
                [-bound, bound - 1, 0, -1, 1],
            ]), jnp.int32)
        limbs = compress.pack_int8_limbs(g, num_limbs)
        assert limbs.dtype == jnp.int8 and limbs.shape == (num_limbs, *g.shape)
        back = compress.unpack_limb_sums(limbs.astype(jnp.int32), 1)
        assert back.dtype == jnp.int32
        np.testing.assert_array_equal(np.asarray(back), np.asarray(g))

    def test_pack_rejects_bad_limb_count(self):
        with pytest.raises(ValueError, match="num_limbs"):
            compress.pack_int8_limbs(jnp.zeros(3, jnp.int32), 5)

    def test_fits_limbs(self):
        g = jnp.asarray([-(2**15), 2**15 - 1], jnp.int32)
        assert bool(compress.fits_limbs(g, 2))
        assert not bool(compress.fits_limbs(g + 1, 2))
        assert bool(compress.fits_limbs(g * 1000, 4))

    @pytest.mark.parametrize("n", [2, 3, 4])
    @pytest.mark.parametrize("num_limbs", [2, 4])
    def test_nitro_compressed_psum_is_exact(self, n, num_limbs):
        """int8-limb wire ≡ plain psum, bit for bit, incl. nested trees."""
        bound = 2 ** (8 * num_limbs - 1) // n  # local range: no sum overflow
        rng = np.random.default_rng(n * 10 + num_limbs)
        tree = {
            "fw": {"w": jnp.asarray(
                rng.integers(-bound, bound, (n, 4, 3)), jnp.int32)},
            "lr": jnp.asarray(rng.integers(-bound, bound, (n, 7)), jnp.int32),
        }
        comp = _ranks(
            lambda t: compress.nitro_compressed_psum(
                t, "r", num_limbs=num_limbs), tree)
        ref = _ranks(lambda t: compress.exact_integer_psum(t, "r"), tree)
        for c, r in zip(jax.tree_util.tree_leaves(comp),
                        jax.tree_util.tree_leaves(ref)):
            assert c.dtype == r.dtype == jnp.int32
            np.testing.assert_array_equal(np.asarray(c), np.asarray(r))


class TestCompressionProperties:
    """Hypothesis properties behind the bitwise-DP claim: integer sums are
    reduction-order invariant, the limb wire format is lossless, and the
    EF float path's error is bounded by its (always power-of-two) scale."""

    @given(st.integers(0, 2**31 - 1), st.integers(2, 5))
    @settings(max_examples=25, deadline=None)
    def test_integer_sum_order_invariant(self, seed, n):
        """Permuting shard order changes the reduction order; int32 sums
        (incl. wraparound) must not care — the property that lets psum,
        ring, and limb reductions disagree on schedule but never result."""
        rng = np.random.default_rng(seed)
        shards = rng.integers(-(2**28), 2**28, (n, 16)).astype(np.int32)
        perm = rng.permutation(n)
        a = _ranks(lambda v: jax.lax.psum(v, "r"), jnp.asarray(shards))
        b = _ranks(lambda v: jax.lax.psum(v, "r"), jnp.asarray(shards[perm]))
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
        ring = _ranks(
            lambda v: coll.ring_all_reduce(v, "r"), jnp.asarray(shards[perm]))
        np.testing.assert_array_equal(np.asarray(ring[0]), np.asarray(a[0]))

    @given(st.integers(0, 2**31 - 1), st.integers(2, 4))
    @settings(max_examples=25, deadline=None)
    def test_limb_psum_order_invariant(self, seed, n):
        rng = np.random.default_rng(seed)
        shards = rng.integers(-(2**28), 2**28, (n, 16)).astype(np.int32)
        perm = rng.permutation(n)
        a = _ranks(
            lambda v: compress.nitro_compressed_psum(v, "r"),
            jnp.asarray(shards))
        b = _ranks(
            lambda v: compress.nitro_compressed_psum(v, "r"),
            jnp.asarray(shards[perm]))
        np.testing.assert_array_equal(np.asarray(a[0]), np.asarray(b[0]))
        np.testing.assert_array_equal(
            np.asarray(a[0]), np.asarray(shards).sum(0, dtype=np.int32))

    @given(st.integers(0, 2**31 - 1), st.floats(1e-8, 1e3))
    @settings(max_examples=40, deadline=None)
    def test_ef_roundtrip_error_within_one_scale_ulp(self, seed, sigma):
        rng = np.random.default_rng(seed)
        g = {"w": jnp.asarray(rng.normal(0, sigma, (64,)), jnp.float32)}
        q, s, _ = compress.compress(g, compress.ef_init(g))
        back = compress.decompress(q, s)
        err = np.abs(np.asarray(back["w"]) - np.asarray(g["w"])).max()
        assert err <= float(s["w"])  # one ulp of the int8 grid

    @given(st.integers(0, 2**31 - 1), st.floats(1e-8, 1e3))
    @settings(max_examples=40, deadline=None)
    def test_ef_scale_is_power_of_two(self, seed, sigma):
        """Pow2 scales divide exactly in binary FP: dequantisation on every
        replica is bit-identical, whatever its libm."""
        rng = np.random.default_rng(seed)
        g = {"w": jnp.asarray(rng.normal(0, sigma, (32,)), jnp.float32)}
        _, s, _ = compress.compress(g, compress.ef_init(g))
        mantissa, _ = np.frexp(float(s["w"]))
        assert mantissa == 0.5  # exactly a power of two


class TestShardingHelpers:
    def test_named_sharding_resolves_rules(self):
        mesh = make_mesh((1, 1), ("data", "model"))
        from repro.parallel.sharding import named_sharding

        ns = named_sharding(mesh, train_rules(), ("batch", "heads"))
        assert ns.spec == jax.sharding.PartitionSpec(("data",), "model")

    def test_tree_shardings_maps_axes_tuples(self):
        """Leaves are tuples-of-axis-names; containers (dicts, NamedTuples
        of tuples) are descended, not treated as leaves."""
        mesh = make_mesh((1, 1), ("data", "model"))
        from repro.parallel.sharding import tree_shardings

        logical = {"x": ("batch", None), "nested": {"w": ("heads",)}}
        out = tree_shardings(mesh, train_rules(), logical)
        assert out["x"].spec == jax.sharding.PartitionSpec(("data",), None)
        assert out["nested"]["w"].spec == jax.sharding.PartitionSpec("model")


class TestPipeline:
    """GPipe scaffolding: the sequential reference schedule and the
    stage-axis ppermute schedule must agree (vmap simulates the ranks)."""

    def test_split_microbatches(self):
        x = jnp.arange(12, dtype=jnp.int32).reshape(6, 2)
        m = pipe.split_microbatches(x, 3)
        assert m.shape == (3, 2, 2)
        np.testing.assert_array_equal(
            np.asarray(m).reshape(6, 2), np.asarray(x))

    def test_sequential_schedule_applies_all_stages(self):
        x = jnp.arange(12, dtype=jnp.int32).reshape(6, 2)
        out = pipe.pipeline_apply(
            lambda s, m: m * 2 + s, x, num_stages=3, num_micro=3)
        # ((x*2+0)*2+1)*2+2 = 8x + 4
        np.testing.assert_array_equal(
            np.asarray(out), np.asarray(x) * 8 + 4)

    @pytest.mark.parametrize("n", [2, 3])
    def test_stage_axis_schedule_matches_sequential(self, n):
        x = jnp.arange(6 * n, dtype=jnp.int32).reshape(3 * n, 2)
        ref = pipe.pipeline_apply(
            lambda s, m: m * 2, x, num_stages=n, num_micro=3)
        stacked = jnp.broadcast_to(x, (n, *x.shape))
        out = jax.vmap(
            lambda v: pipe.pipeline_apply(
                lambda s, m: m * 2, v,
                num_stages=n, num_micro=3, axis_name="r"),
            axis_name="r")(stacked)
        # completed microbatches drain through the last stage
        np.testing.assert_array_equal(np.asarray(out[n - 1]), np.asarray(ref))

    def test_bubble_fraction(self):
        assert pipe.bubble_fraction(1, 8) == 0.0
        assert pipe.bubble_fraction(4, 8) == pytest.approx(3 / 11)


class TestFaultTolerance:
    def test_straggler_detector_flags_slow_steps(self):
        det = ft.StragglerDetector(threshold=2.0, warmup_steps=3)
        for _ in range(10):
            det.record(1.0)
        assert not det.should_rebalance(1)
        assert det.record(5.0)  # straggler
        assert det.incidents == 1
        # EWMA not poisoned by the straggler
        assert det.ewma < 1.5

    def test_preemption_guard_simulation(self):
        guard = ft.PreemptionGuard(install=False)
        assert not guard.requested
        guard.simulate()
        assert guard.requested

    def test_elastic_policy_chooses_divisible_mesh(self):
        pol = ft.ElasticPolicy(model_parallel=16, global_batch=256)
        assert pol.choose_mesh_shape(256) == (16, 16)
        # lost 32 chips → 14 data slices don't divide 256 → fall to 8
        assert pol.choose_mesh_shape(224) == (8, 16)
        with pytest.raises(RuntimeError):
            pol.choose_mesh_shape(15)


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(12, dtype=jnp.int32).reshape(3, 4),
                "b": [jnp.ones((2,)), jnp.zeros((5,), jnp.bfloat16)]}
        ckpt.save(str(tmp_path), 7, tree, extra={"note": "x"})
        like = jax.tree_util.tree_map(jnp.zeros_like, tree)
        restored, step = ckpt.restore(str(tmp_path), like)
        assert step == 7
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b, np.float32)
            )

    def test_latest_ignores_partial(self, tmp_path):
        tree = {"a": jnp.ones((2,))}
        ckpt.save(str(tmp_path), 1, tree)
        # simulate a preempted writer: manifest missing
        os.makedirs(tmp_path / "step_00000002")
        (tmp_path / "LATEST").write_text("step_00000002")
        assert ckpt.latest_step(str(tmp_path)) is None  # refuses partial

    def test_async_checkpointer(self, tmp_path):
        tree = {"a": jnp.full((1000,), 3.0)}
        ac = ckpt.AsyncCheckpointer(str(tmp_path))
        ac.save(3, tree)
        ac.wait()
        restored, step = ckpt.restore(str(tmp_path), tree)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))

    def test_elastic_restore_resharding_hook(self, tmp_path):
        """Restore accepts shardings — single-device here, resharded meshes
        exercised by the dry-run; this validates the API path."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = make_mesh((1,), ("data",))
        tree = {"w": jnp.arange(16, dtype=jnp.float32)}
        ckpt.save(str(tmp_path), 1, tree)
        shardings = {"w": NamedSharding(mesh, P("data"))}
        restored, _ = ckpt.restore(str(tmp_path), tree, shardings=shardings)
        assert restored["w"].sharding == shardings["w"]


class TestLoader:
    def test_sharded_loader_prefetches(self):
        from repro.data.loader import ShardedLoader, synthetic_lm_generator

        gen = synthetic_lm_generator(1000, 16, global_batch=8)
        loader = ShardedLoader(
            gen, global_batch=8, process_index=0, process_count=1
        )
        b = next(loader)
        assert b["tokens"].shape == (8, 16)
        assert b["labels"].shape == (8, 16)
        # next-token alignment
        g0 = gen(0)
        np.testing.assert_array_equal(g0["tokens"][:, 1:], g0["labels"][:, :-1])
        loader.close()

    def test_local_slice_partitions_batch(self):
        from repro.data.loader import ShardedLoader, synthetic_lm_generator

        gen = synthetic_lm_generator(1000, 8, global_batch=8)
        l0 = ShardedLoader(gen, global_batch=8, process_index=0, process_count=2)
        l1 = ShardedLoader(gen, global_batch=8, process_index=1, process_count=2)
        b0, b1 = next(l0), next(l1)
        full = gen(0)
        np.testing.assert_array_equal(
            np.concatenate([b0["tokens"], b1["tokens"]]), full["tokens"]
        )
        l0.close(); l1.close()
