"""Distribution substrate: sharding rules, ring collectives, gradient
compression, fault tolerance, checkpointing, data loader.

These run on CPU with a handful of forced host devices (set per-test via
shard_map over a 1-device mesh where possible; multi-device semantics are
covered by the dry-run)."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.mesh import make_mesh
from repro.parallel import collectives as coll
from repro.parallel import compress
from repro.parallel.sharding import (
    resolve,
    serve_rules,
    shard,
    train_rules,
    use_rules,
)
from repro.train import checkpoint as ckpt
from repro.train import fault_tolerance as ft


class TestShardingRules:
    def test_no_context_is_noop(self):
        x = jnp.ones((4, 4))
        y = shard(x, "batch", "embed")
        assert y is x

    def test_rule_tables_cover_model_axes(self):
        r = train_rules(multi_pod=True)
        assert r["batch"] == ("pod", "data")
        assert r["heads"] == "model"
        assert r["p_fsdp"] == "data"
        s = serve_rules()
        assert s["p_fsdp"] is None  # weights replicated over data at serve

    def test_resolve_inside_context(self):
        mesh = make_mesh((1, 1), ("data", "model"))
        with use_rules(mesh, train_rules()):
            spec = resolve(("batch", None, "heads"))
            assert spec == jax.sharding.PartitionSpec(("data",), None, "model")

    def test_constraint_applies_in_jit(self):
        mesh = make_mesh((1, 1), ("data", "model"))

        def f(x):
            with use_rules(mesh, train_rules()):
                return shard(x * 2, "batch", "embed")

        y = jax.jit(f)(jnp.ones((4, 8)))
        np.testing.assert_array_equal(np.asarray(y), 2 * np.ones((4, 8)))


class TestRingCollectives:
    def _shmap(self, fn, n, *args):
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        mesh = make_mesh((n,), ("x",))
        return shard_map(
            fn, mesh=mesh, in_specs=P("x"), out_specs=P("x")
        )(*args)

    def test_ring_all_reduce_single_device(self):
        x = jnp.arange(8.0)
        out = self._shmap(lambda v: coll.ring_all_reduce(v, "x"), 1, x)
        np.testing.assert_allclose(np.asarray(out), np.asarray(x))

    def test_ring_all_reduce_matches_psum(self):
        n = jax.device_count()
        if n < 2:
            pytest.skip("needs >1 device (covered by dry-run on 512)")
        x = jnp.arange(float(8 * n))
        ring = self._shmap(lambda v: coll.ring_all_reduce(v, "x"), n, x)
        ref = self._shmap(lambda v: jax.lax.psum(v, "x"), n, x)
        np.testing.assert_allclose(np.asarray(ring), np.asarray(ref))


class TestGradientCompression:
    def test_roundtrip_error_bounded(self):
        rng = np.random.default_rng(0)
        g = {"w": jnp.asarray(rng.normal(0, 1e-3, (256,)), jnp.float32)}
        ef = compress.ef_init(g)
        q, s, ef = compress.compress(g, ef)
        assert q["w"].dtype == jnp.int8
        back = compress.decompress(q, s)
        err = np.abs(np.asarray(back["w"] - g["w"])).max()
        assert err <= float(s["w"]) / 2 + 1e-12  # half-ulp of the int8 grid

    def test_error_feedback_accumulates(self):
        """EF: the quantisation residual re-enters the next step — the
        *running sum* of compressed gradients tracks the true sum."""
        rng = np.random.default_rng(1)
        true_sum = np.zeros(64, np.float32)
        comp_sum = np.zeros(64, np.float32)
        g0 = {"w": jnp.zeros((64,), jnp.float32)}
        ef = compress.ef_init(g0)
        for i in range(30):
            g = rng.normal(0, 1e-4, 64).astype(np.float32)
            true_sum += g
            q, s, ef = compress.compress({"w": jnp.asarray(g)}, ef)
            comp_sum += np.asarray(compress.decompress(q, s)["w"])
        resid = np.abs(np.asarray(ef.residual["w"])).max()
        # EF invariant: |Σtrue − Σcompressed| == |residual| (bounded, no drift)
        drift = np.abs(true_sum - comp_sum).max()
        assert drift <= resid + 1e-6

    def test_integer_gradients_sum_exactly(self):
        """NITRO path: int32 gradient reduction is exact (no compression)."""
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        mesh = make_mesh((1,), ("pod",))
        g = {"w": jnp.asarray([2**30, -(2**30), 123], jnp.int32)}
        out = shard_map(
            lambda t: compress.exact_integer_psum(t, "pod"),
            mesh=mesh, in_specs=P(), out_specs=P(),
        )(g)
        np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(g["w"]))


class TestFaultTolerance:
    def test_straggler_detector_flags_slow_steps(self):
        det = ft.StragglerDetector(threshold=2.0, warmup_steps=3)
        for _ in range(10):
            det.record(1.0)
        assert not det.should_rebalance(1)
        assert det.record(5.0)  # straggler
        assert det.incidents == 1
        # EWMA not poisoned by the straggler
        assert det.ewma < 1.5

    def test_preemption_guard_simulation(self):
        guard = ft.PreemptionGuard(install=False)
        assert not guard.requested
        guard.simulate()
        assert guard.requested

    def test_elastic_policy_chooses_divisible_mesh(self):
        pol = ft.ElasticPolicy(model_parallel=16, global_batch=256)
        assert pol.choose_mesh_shape(256) == (16, 16)
        # lost 32 chips → 14 data slices don't divide 256 → fall to 8
        assert pol.choose_mesh_shape(224) == (8, 16)
        with pytest.raises(RuntimeError):
            pol.choose_mesh_shape(15)


class TestCheckpoint:
    def test_save_restore_roundtrip(self, tmp_path):
        tree = {"a": jnp.arange(12, dtype=jnp.int32).reshape(3, 4),
                "b": [jnp.ones((2,)), jnp.zeros((5,), jnp.bfloat16)]}
        ckpt.save(str(tmp_path), 7, tree, extra={"note": "x"})
        like = jax.tree_util.tree_map(jnp.zeros_like, tree)
        restored, step = ckpt.restore(str(tmp_path), like)
        assert step == 7
        for a, b in zip(jax.tree_util.tree_leaves(tree),
                        jax.tree_util.tree_leaves(restored)):
            np.testing.assert_array_equal(
                np.asarray(a, np.float32), np.asarray(b, np.float32)
            )

    def test_latest_ignores_partial(self, tmp_path):
        tree = {"a": jnp.ones((2,))}
        ckpt.save(str(tmp_path), 1, tree)
        # simulate a preempted writer: manifest missing
        os.makedirs(tmp_path / "step_00000002")
        (tmp_path / "LATEST").write_text("step_00000002")
        assert ckpt.latest_step(str(tmp_path)) is None  # refuses partial

    def test_async_checkpointer(self, tmp_path):
        tree = {"a": jnp.full((1000,), 3.0)}
        ac = ckpt.AsyncCheckpointer(str(tmp_path))
        ac.save(3, tree)
        ac.wait()
        restored, step = ckpt.restore(str(tmp_path), tree)
        assert step == 3
        np.testing.assert_array_equal(np.asarray(restored["a"]), np.asarray(tree["a"]))

    def test_elastic_restore_resharding_hook(self, tmp_path):
        """Restore accepts shardings — single-device here, resharded meshes
        exercised by the dry-run; this validates the API path."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        mesh = make_mesh((1,), ("data",))
        tree = {"w": jnp.arange(16, dtype=jnp.float32)}
        ckpt.save(str(tmp_path), 1, tree)
        shardings = {"w": NamedSharding(mesh, P("data"))}
        restored, _ = ckpt.restore(str(tmp_path), tree, shardings=shardings)
        assert restored["w"].sharding == shardings["w"]


class TestLoader:
    def test_sharded_loader_prefetches(self):
        from repro.data.loader import ShardedLoader, synthetic_lm_generator

        gen = synthetic_lm_generator(1000, 16, global_batch=8)
        loader = ShardedLoader(
            gen, global_batch=8, process_index=0, process_count=1
        )
        b = next(loader)
        assert b["tokens"].shape == (8, 16)
        assert b["labels"].shape == (8, 16)
        # next-token alignment
        g0 = gen(0)
        np.testing.assert_array_equal(g0["tokens"][:, 1:], g0["labels"][:, :-1])
        loader.close()

    def test_local_slice_partitions_batch(self):
        from repro.data.loader import ShardedLoader, synthetic_lm_generator

        gen = synthetic_lm_generator(1000, 8, global_batch=8)
        l0 = ShardedLoader(gen, global_batch=8, process_index=0, process_count=2)
        l1 = ShardedLoader(gen, global_batch=8, process_index=1, process_count=2)
        b0, b1 = next(l0), next(l1)
        full = gen(0)
        np.testing.assert_array_equal(
            np.concatenate([b0["tokens"], b1["tokens"]]), full["tokens"]
        )
        l0.close(); l1.close()
