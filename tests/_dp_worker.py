"""Subprocess worker for the data-parallel bitwise-identity suite.

``XLA_FLAGS=--xla_force_host_platform_device_count=N`` only takes effect
before the XLA backend initialises, and the pytest process has long since
initialised it with 1 device — so every (device count, reducer) cell of
``tests/test_data_parallel.py`` runs in a fresh interpreter via this
script.  The worker:

  1. forces the requested host-device count *before* importing jax;
  2. builds a deterministic config + dataset (identical in every worker —
     everything derives from fixed seeds);
  3. runs a multi-step trajectory through either the single-device
     ``les.train_step`` (``--reducer single``, the reference) or the
     sharded ``dp.dp_train_step`` with the requested reducer;
  4. asserts the whole step jaxpr is float-free (descending into the
     shard_map sub-jaxpr) — a failed assert fails the subprocess;
  5. writes final-state leaves, per-step metrics and (optionally) the
     telemetry pytree to an ``.npz`` the test compares bitwise.

Run by the ``dp_run`` fixture; also usable by hand:

    python tests/_dp_worker.py --out /tmp/t.npz --devices 4 --reducer ring
"""

from __future__ import annotations

import argparse
import os
import sys


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", required=True)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--reducer", default="single",
                    choices=("single", "psum", "ring", "compress"))
    ap.add_argument("--config", default="tiny", choices=("tiny", "vgg8b"))
    ap.add_argument("--steps", type=int, default=3)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--telemetry", action="store_true")
    ap.add_argument("--fuse-opt", action="store_true",
                    help="single: the fused-epilogue train_step fast "
                         "path; DP: the post-reduce fused IntegerSGD "
                         "apply — both bitwise-identical to the default")
    args = ap.parse_args()

    # must precede the first jax import anywhere in the process
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") +
        f" --xla_force_host_platform_device_count={args.devices}"
    ).strip()

    # self-sufficient import path: the launching pytest may not have been
    # started with PYTHONPATH=src (e.g. under tools/cov_gate.py)
    src = os.path.join(os.path.dirname(os.path.abspath(__file__)),
                       os.pardir, "src")
    if os.path.isdir(src) and src not in sys.path:
        sys.path.insert(0, src)

    import jax
    import jax.numpy as jnp
    import numpy as np

    assert jax.device_count() == args.devices, (
        jax.device_count(), args.devices)

    from _gradcheck import assert_jaxpr_integer_only
    from repro.core import blocks as B
    from repro.core import les
    from repro.core import model as M

    if args.config == "tiny":
        cfg = M.NitroConfig(
            blocks=(
                B.BlockSpec(kind="conv", out_features=16, pool=True,
                            d_lr=256, dropout=0.1),
                B.BlockSpec(kind="linear", out_features=64, dropout=0.1),
            ),
            input_shape=(8, 8, 3), num_classes=10, gamma_inv=512,
        )
    else:  # paper VGG8B at CPU-test scale
        from repro.configs import get_paper_config
        cfg = get_paper_config("vgg8b", scale=0.0625,
                               input_shape=(16, 16, 3))

    rng = np.random.default_rng(0)
    x = jnp.asarray(
        rng.integers(-128, 128, (args.batch, *cfg.input_shape)), jnp.int32)
    labels = jnp.asarray(
        rng.integers(0, cfg.num_classes, (args.batch,)), jnp.int32)
    state = les.create_train_state(jax.random.PRNGKey(0), cfg)

    if args.reducer == "single":
        def step(state, x, labels, key):
            return les.train_step(state, cfg, x, labels, key,
                                  fuse_opt=args.fuse_opt,
                                  telemetry=args.telemetry)
    else:
        from repro.parallel import dp
        mesh = dp.data_mesh(args.devices)

        def step(state, x, labels, key):
            return dp.dp_train_step(state, cfg, x, labels, key,
                                    mesh=mesh, dp_reduce=args.reducer,
                                    fuse_opt=args.fuse_opt,
                                    telemetry=args.telemetry)

    # the whole sharded step must stay integer-only — iter_eqns descends
    # into the shard_map/pjit sub-jaxprs, so the interior is covered too
    jaxpr = jax.make_jaxpr(step)(state, x, labels, jax.random.PRNGKey(100))
    assert_jaxpr_integer_only(jaxpr)

    step = jax.jit(step)
    losses, corrects, locals_ = [], [], []
    telem = None
    for i in range(args.steps):
        out = step(state, x, labels, jax.random.PRNGKey(100 + i))
        state, metrics = out[0], out[1]
        if args.telemetry:
            telem = out[2]
        losses.append(np.asarray(metrics.loss))
        corrects.append(np.asarray(metrics.correct))
        locals_.append(np.asarray(metrics.local_losses))

    payload = {
        "loss": np.stack(losses),
        "correct": np.stack(corrects),
        "local_losses": np.stack(locals_),
        "float_free": np.asarray(1, np.int32),
    }
    for i, leaf in enumerate(jax.tree_util.tree_leaves(state)):
        payload[f"state_{i:03d}"] = np.asarray(leaf)
    if telem is not None:
        # the `dp` entry (shard count, limb-fit flag) is topology-scoped
        # by design — drop it so the telemetry leaves compare bitwise
        # across device counts; assert its shape here instead
        dp_extra = telem.pop("dp", None)
        if args.reducer != "single":
            assert dp_extra is not None
            assert int(dp_extra["shards"]) == args.devices
            assert int(dp_extra["grad_fits_int16"]) in (0, 1)
        for i, leaf in enumerate(jax.tree_util.tree_leaves(telem)):
            payload[f"telem_{i:03d}"] = np.asarray(leaf)
    np.savez(args.out, **payload)


if __name__ == "__main__":
    sys.exit(main())
