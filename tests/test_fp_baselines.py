"""FP LES / FP BP baselines (paper Tables 1–2 comparison columns)."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import fp_baselines as fp
from repro.core.blocks import BlockSpec
from repro.core.model import NitroConfig


@pytest.fixture(scope="module")
def setup():
    cfg = NitroConfig(
        blocks=(BlockSpec("conv", 16, pool=True, d_lr=256), BlockSpec("linear", 64)),
        input_shape=(8, 8, 3), num_classes=10,
    )
    rng = np.random.default_rng(0)
    templates = rng.integers(-60, 61, (10, 8, 8, 3))
    y = rng.integers(0, 10, 64).astype(np.int32)
    x = np.clip(templates[y] + rng.integers(-40, 41, (64, 8, 8, 3)), -127, 127)
    return cfg, jnp.asarray(x, jnp.float32) / 64.0, jnp.asarray(y)


class TestFPBP:
    def test_learns(self, setup):
        cfg, x, y = setup
        params = fp.init_fp_params(jax.random.PRNGKey(0), cfg)
        opt_state = fp.adam_init(params)
        step = jax.jit(functools.partial(fp.train_step_bp, cfg=cfg))
        losses = []
        for i in range(60):
            params, opt_state, loss = step(
                params, opt_state, x=x, labels=y, key=jax.random.PRNGKey(i)
            )
            losses.append(float(loss))
        assert losses[-1] < 0.5 * losses[0]
        assert int(fp.accuracy_fp(params, cfg, x, y)) > 32


class TestFPLES:
    def test_learns_with_confined_gradients(self, setup):
        cfg, x, y = setup
        params = fp.init_fp_params(jax.random.PRNGKey(0), cfg)
        step = jax.jit(functools.partial(fp.train_step_les, cfg=cfg, lr=2e-2))
        losses = []
        for i in range(150):
            params, loss = step(params, x=x, labels=y, key=jax.random.PRNGKey(i))
            losses.append(float(loss))
        assert losses[-1] < 0.8 * losses[0]
        assert int(fp.accuracy_fp(params, cfg, x, y)) > 20  # 64 samples, chance 6.4

    def test_stop_gradient_confines(self, setup):
        """Gradient of block-0 params wrt LES loss is unaffected by
        downstream weight perturbation (same invariant as integer LES)."""
        cfg, x, y = setup
        params = fp.init_fp_params(jax.random.PRNGKey(0), cfg)
        g1 = jax.grad(fp.loss_les)(params, cfg, x, y, jax.random.PRNGKey(0))
        params2 = jax.tree_util.tree_map(lambda p: p, params)
        params2["output"] = params2["output"] + 0.5
        params2["blocks"][1]["fw"] = params2["blocks"][1]["fw"] * 1.1
        g2 = jax.grad(fp.loss_les)(params2, cfg, x, y, jax.random.PRNGKey(0))
        np.testing.assert_allclose(
            np.asarray(g1["blocks"][0]["fw"]), np.asarray(g2["blocks"][0]["fw"]),
            rtol=1e-6,
        )
